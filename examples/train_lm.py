"""Train an assigned-architecture LM end to end (fault-tolerant loop,
async checkpoints, deterministic resumable data).

Thin wrapper over the production launcher; smoke-scale by default so it
finishes on the CPU container, full configs behind --no-smoke:

    PYTHONPATH=src python examples/train_lm.py --arch qwen3-32b --steps 60
    PYTHONPATH=src python examples/train_lm.py --arch mamba2-370m \
        --steps 300 --no-smoke     # ~100M-class model, real shapes
"""
import argparse
import sys

from repro.launch.train import main as launch_main


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="phi3-mini-3.8b")
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--no-smoke", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/lm_ckpt")
    args = ap.parse_args()

    argv = ["--arch", args.arch, "--steps", str(args.steps),
            "--ckpt-dir", args.ckpt_dir, "--ckpt-every", "25"]
    if not args.no_smoke:
        argv.append("--smoke")
    launch_main(argv)


if __name__ == "__main__":
    main()
