"""Quickstart: BLESS leverage-score sampling + FALKON-BLESS in ~40 lines.

    PYTHONPATH=src python examples/quickstart.py

Every entry point below picks its kernel-operator backend by platform
heuristic; pin one without code edits via the env var, e.g.
``REPRO_BACKEND=pallas python examples/quickstart.py`` (the richer examples
also take an explicit ``--backend`` flag).
"""
import jax
import jax.numpy as jnp

from repro.core import (bless, exact_rls, falkon_bless_fit, make_kernel)

# --- data: clustered inputs => low effective dimension (the regime
# leverage scores are built for) -------------------------------------------
key = jax.random.PRNGKey(0)
kc, ka, kn, ky = jax.random.split(key, 4)
n, d = 2000, 8
centers = jax.random.normal(kc, (10, d)) * 3.0
x = centers[jax.random.randint(ka, (n,), 0, 10)] + 0.4 * jax.random.normal(kn, (n, d))
y = jnp.sin(2 * x[:, 0]) * jnp.tanh(x[:, 1]) + 0.05 * jax.random.normal(ky, (n,))

kern = make_kernel("gaussian", sigma=2.0)
lam = 1e-3

# --- 1. approximate leverage scores with BLESS (Alg. 1) ---------------------
res = bless(jax.random.PRNGKey(1), x, kern, lam, q1=4.0, q2=4.0)
print(f"BLESS: {len(res.levels)} ladder levels, final |J| = {res.final.m_h} "
      f"(d_eff estimate {res.final.d_h:.1f})")

ell = exact_rls(kern, x, lam)  # O(n^3) oracle, for demonstration only
racc = res.scores(kern, x) / ell
print(f"score accuracy: mean R-ACC {float(racc.mean()):.3f}, "
      f"5th/95th pct {float(jnp.quantile(racc, .05)):.2f}/{float(jnp.quantile(racc, .95)):.2f}")

# --- 2. FALKON-BLESS: preconditioned CG ridge regression on BLESS centers ---
model = falkon_bless_fit(jax.random.PRNGKey(2), kern, x, y,
                         lam_bless=1e-3, lam_falkon=1e-5, iters=25, m_cap=400)
mse = float(jnp.mean((model.predict(x) - y) ** 2))
print(f"FALKON-BLESS: M = {model.centers.shape[0]} centers, "
      f"train MSE {mse:.4f} (var(y) = {float(jnp.var(y)):.4f})")
