"""Quickstart: the ``repro.api`` front door in ~40 lines — pluggable
sampler, sklearn-style estimator, swappable kernel family.

    PYTHONPATH=src python examples/quickstart.py

Every entry point below picks its kernel-operator backend by platform
heuristic; pin one without code edits via the env var, e.g.
``REPRO_BACKEND=pallas python examples/quickstart.py`` (the richer examples
also take an explicit ``--backend`` flag).
"""
import jax
import jax.numpy as jnp

from repro.api import (BlessSampler, ExactRlsSampler, FalkonRegressor,
                       FitConfig, KFoldSweep, kernel_family_names, make_kernel)
from repro.core import approx_rls_all, exact_rls

# --- data: clustered inputs => low effective dimension (the regime
# leverage scores are built for) -------------------------------------------
key = jax.random.PRNGKey(0)
kc, ka, kn, ky = jax.random.split(key, 4)
n, d = 2000, 8
centers = jax.random.normal(kc, (10, d)) * 3.0
x = centers[jax.random.randint(ka, (n,), 0, 10)] + 0.4 * jax.random.normal(kn, (n, d))
y = jnp.sin(2 * x[:, 0]) * jnp.tanh(x[:, 1]) + 0.05 * jax.random.normal(ky, (n,))

kern = make_kernel("gaussian", sigma=2.0)
lam = 1e-3

# --- 1. approximate leverage scores with BLESS (Alg. 1) ---------------------
sampler = BlessSampler(lam=lam, q1=4.0, q2=4.0)
res = sampler.ladder(jax.random.PRNGKey(1), x, kern)  # the full lam path
print(f"BLESS: {len(res.levels)} ladder levels, final |J| = {res.final.m_h} "
      f"(d_eff estimate {res.final.d_h:.1f})")

ell = exact_rls(kern, x, lam)  # O(n^3) oracle, for demonstration only
racc = approx_rls_all(kern, x, res.final.centers, jnp.asarray(lam)) / ell
print(f"score accuracy: mean R-ACC {float(racc.mean()):.3f}, "
      f"5th/95th pct {float(jnp.quantile(racc, .05)):.2f}/{float(jnp.quantile(racc, .95)):.2f}")

# --- 2. FALKON-BLESS: sampler slot + estimator slot, composed ---------------
est = FalkonRegressor(kernel=kern,
                      sampler=BlessSampler(lam=1e-3, q2=3.0, m_cap=400),
                      config=FitConfig(lam=1e-5, iters=25, seed=2))
est.fit(x, y)
mse = float(jnp.mean((est.predict(x) - y) ** 2))
print(f"FALKON-BLESS: M = {est.centers_.shape[0]} centers, "
      f"train MSE {mse:.4f} (R^2 {est.score(x, y):.3f})")

# --- 3. the slots are swappable: oracle sampler, another kernel family ------
est_oracle = FalkonRegressor(kernel="matern32", sigma=2.0,
                             sampler=ExactRlsSampler(m=300, lam=lam),
                             config=FitConfig(lam=1e-5, iters=25, seed=3))
est_oracle.fit(x, y)
print(f"matern32 + exact-RLS oracle sampler: R^2 {est_oracle.score(x, y):.3f} "
      f"(families available: {kernel_family_names()})")

# --- 4. multi-output: k targets ride ONE multi-RHS block-CG -----------------
# The K_nM streaming (the dominant fit cost) is shared by every column, so
# the extra outputs below cost GEMM flops, not extra kernel evaluations.
Y = jnp.stack([y, jnp.cos(x[:, 2]) * x[:, 0], -0.5 * y + 1.0], axis=1)
est_multi = FalkonRegressor(kernel=kern,
                            sampler=BlessSampler(lam=1e-3, q2=3.0, m_cap=400),
                            config=FitConfig(lam=1e-5, iters=25, seed=2))
est_multi.fit(x, Y)
print(f"multi-output: alpha {est_multi.model_.alpha.shape}, "
      f"predict {est_multi.predict(x[:5]).shape}, R^2 {est_multi.score(x, Y):.3f}")

# --- 5. KFoldSweep: lambda selection with CV folds as RHS columns -----------
# Per lambda: ONE multi-RHS solve (folds = columns, fold-masked targets) on
# warm-started centers; the whole grid after the first fit is jit cache hits.
sweep = KFoldSweep(kernel=kern, sampler=BlessSampler(lam=1e-3, m_cap=400),
                   lams=(1e-3, 1e-5, 1e-7), folds=5, iters=25)
res = sweep.run(x, y)
scores = ", ".join(f"lam={ell:g}: {float(s):.4f}"
                   for ell, s in zip(res.lams, res.mean_scores))
print(f"KFoldSweep held-out MSE ({scores}) -> best lam {res.best_lam:g} "
      f"[{len(res.lams)} solves instead of {len(res.lams) * 5} fits]")
