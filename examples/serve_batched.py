"""Serve a small model with batched requests (continuous batching) and
demonstrate BLESS leverage-score KV-cache compression — the paper's
technique as a serving feature.

    PYTHONPATH=src python examples/serve_batched.py
"""
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, smoke
from repro.data import SyntheticLM
from repro.models.attention import bless_compress_cache
from repro.optim import OptConfig
from repro.serving.engine import ServeEngine
from repro.training import make_train_step, train_state_init


def main() -> None:
    cfg = smoke(get_config("qwen3-32b"))
    print(f"arch: {cfg.name} ({cfg.n_layers}L d={cfg.d_model})")

    # brief training so generations follow the synthetic rule
    state = train_state_init(cfg, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(cfg, OptConfig(peak_lr=3e-3, warmup=5,
                                                  total_steps=40), loss_chunks=4))
    pipe = SyntheticLM(cfg.vocab_size, batch=8, seq=64, seed=0, noise=0.05)
    for s in range(40):
        state, m = step(state, pipe.batch_at(s))
    print(f"pre-trained 40 steps, loss {float(m['loss']):.3f}")

    # continuous batching: requests arrive at different times
    eng = ServeEngine(params=state.params, cfg=cfg, max_len=64, batch_slots=4)
    perm = pipe._rule()
    eng.add_request(0, [int(perm[7]), int(perm[perm[7]])])
    eng.add_request(1, [3, int(perm[3])])
    t0 = time.time()
    n_steps = 12
    for i in range(n_steps):
        if i == 4:  # a request joins mid-flight
            eng.add_request(2, [11])
        eng.step()
    dt = time.time() - t0
    done = sum(1 for i in range(3))
    for slot in range(3):
        print(f"slot {slot}: {eng.finish(slot)}")
    print(f"{n_steps} decode steps x active slots in {dt:.2f}s "
          f"({n_steps * 3 / dt:.1f} tok/s aggregate)")

    # --- BLESS KV compression: keep the top-RLS keys, decode against M << S
    from repro.models import init_cache

    b, s_full, m_keep = 2, 64, 16
    kv = init_cache(cfg, b, s_full)
    layer0 = kv[next(iter(kv))]
    if "k" in layer0:
        k = jax.random.normal(jax.random.PRNGKey(1), layer0["k"].shape[1:], jnp.bfloat16)
        v = jax.random.normal(jax.random.PRNGKey(2), layer0["v"].shape[1:], jnp.bfloat16)
        kc, vc = bless_compress_cache(k.astype(jnp.float32), v.astype(jnp.float32),
                                      m=m_keep)
        print(f"KV compression: {k.shape} -> {kc.shape} "
              f"({s_full / m_keep:.0f}x less KV traffic per decoded token)")


if __name__ == "__main__":
    main()
