"""End-to-end driver (the paper's kind of workload): large-scale kernel
ridge classification with the full production pipeline —

  BLESS center selection -> distributed FALKON CG (data-parallel over all
  local devices) -> evaluation -> model checkpoint.

Mirrors the paper's SUSY experiment shape (Sec. 4) at CPU-container scale:
n = 50_000 points, lam_bless >> lam_falkon, ~10^2-10^3 Nystrom centers.

    PYTHONPATH=src python examples/falkon_endtoend.py [--n 50000]
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.api import BlessSampler, FalkonRegressor, FitConfig, make_kernel
from repro.checkpoint import save_checkpoint
from repro.core.distributed import data_mesh, falkon_fit_distributed


def susy_like(n: int, d: int = 18, seed: int = 0):
    """Two-class data with SUSY-ish dimensionality: a smooth nonlinear
    decision boundary living on a low-dimensional subspace + nuisance dims
    (the low-effective-dimension regime leverage scores exploit)."""
    key = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(key, 3)
    x = jax.random.normal(k1, (n, d))
    w1, w2 = jax.random.normal(k2, (2, d)) / jnp.sqrt(d)
    margin = jnp.tanh(2 * x @ w1) + 0.5 * (x @ w2) ** 2 - 0.5
    y = jnp.sign(margin + 0.1 * jax.random.normal(k3, (n,)))
    return x, jnp.where(y == 0, 1.0, y)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=50_000)
    ap.add_argument("--lam-bless", type=float, default=1e-4)
    ap.add_argument("--lam-falkon", type=float, default=1e-6)
    ap.add_argument("--iters", type=int, default=20)
    ap.add_argument("--m-cap", type=int, default=1500)
    ap.add_argument("--ckpt", default="/tmp/falkon_ckpt")
    ap.add_argument("--backend", choices=["auto", "jnp", "pallas", "sharded", "stream"],
                    default="auto",
                    help="kernel-operator backend (auto: BLESS by platform "
                         "heuristic / REPRO_BACKEND env, FALKON data-parallel)")
    args = ap.parse_args()
    backend = None if args.backend == "auto" else args.backend

    n_test = 8000
    xa, ya = susy_like(args.n + n_test)  # one rule; held-out split
    x, y, xte, yte = xa[: args.n], ya[: args.n], xa[args.n:], ya[args.n:]
    kern = make_kernel("gaussian", sigma=4.0)  # the paper's SUSY sigma

    sampler = BlessSampler(lam=args.lam_bless, q1=3.0, q2=3.0, m_cap=args.m_cap)
    t0 = time.time()
    res = sampler.ladder(jax.random.PRNGKey(0), x, kern, backend=backend)
    t_bless = time.time() - t0
    m = res.final.m_h
    print(f"BLESS: {len(res.levels)} levels, M = {m} centers in {t_bless:.1f}s "
          f"(n = {args.n}; candidate sets never exceeded "
          f"{max(l.r_h for l in res.levels)} points — the 1/lam bound)")

    t0 = time.time()
    if backend is None or backend == "sharded":
        mesh = data_mesh()
        print(f"FALKON: data-parallel CG over {mesh.devices.size} device(s)")
        model = falkon_fit_distributed(
            mesh, kern, x, y, x[res.final.centers.idx[:m]], args.lam_falkon,
            a_diag=res.final.centers.weight[:m], iters=args.iters)
    else:
        print(f"FALKON: CG on the {backend!r} backend")
        est = FalkonRegressor(kernel=kern, sampler=sampler,
                              config=FitConfig(lam=args.lam_falkon,
                                               iters=args.iters, backend=backend))
        # the ladder above already sampled (J, A): hand it straight to fit
        model = est.fit(x, y, center_set=res.final.centers).model_
    t_falkon = time.time() - t0

    pred_tr = jnp.sign(model.predict(x[:10000]))
    pred_te = jnp.sign(model.predict(xte))
    err_tr = float(jnp.mean(pred_tr != y[:10000]))
    err_te = float(jnp.mean(pred_te != yte))
    print(f"FALKON-BLESS: {args.iters} CG iters in {t_falkon:.1f}s | "
          f"train err {err_tr:.4f} | test err {err_te:.4f}")

    path = save_checkpoint(args.ckpt, 0, {
        "centers": model.centers, "alpha": model.alpha,
        "sigma": jnp.asarray(4.0), "lam": jnp.asarray(args.lam_falkon)})
    print(f"model checkpoint -> {path}")


if __name__ == "__main__":
    main()
