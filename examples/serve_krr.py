"""Serve a FALKON-BLESS kernel ridge model under bursty request traffic —
the paper's estimator as a production endpoint.

Fits FALKON-BLESS once, then replays a trace of variable-size prediction
requests through ``KrrServer``: requests are packed into waves, padded to
pow2 row buckets, and served by single fused ``knm_matvec`` dispatches
through the kernel-operator backend seam. Compare the dispatch count with
the naive one-dispatch-per-request path it replaces.

    PYTHONPATH=src python examples/serve_krr.py [--backend jnp|pallas|sharded]
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.api import (BlessSampler, FalkonRegressor, FitConfig, KrrServer,
                       make_kernel)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=4000)
    ap.add_argument("--requests", type=int, default=200)
    ap.add_argument("--backend", choices=["auto", "jnp", "pallas", "sharded", "stream"],
                    default="auto", help="kernel-operator backend override")
    args = ap.parse_args()
    backend = None if args.backend == "auto" else args.backend

    # --- fit once (clustered data: the low-d_eff regime BLESS exploits) ----
    key = jax.random.PRNGKey(0)
    kc, ka, kn, ky = jax.random.split(key, 4)
    n, d = args.n, 8
    cl = jax.random.normal(kc, (10, d)) * 3.0
    x = cl[jax.random.randint(ka, (n,), 0, 10)] + 0.4 * jax.random.normal(kn, (n, d))
    y = jnp.sin(2 * x[:, 0]) * jnp.tanh(x[:, 1]) + 0.05 * jax.random.normal(ky, (n,))
    kern = make_kernel("gaussian", sigma=2.0)
    t0 = time.perf_counter()
    est = FalkonRegressor(kernel=kern, sampler=BlessSampler(lam=1e-3, m_cap=400),
                          config=FitConfig(lam=1e-5, iters=20, backend=backend))
    est.fit(x, y, key=jax.random.PRNGKey(1))
    model = est.model_
    print(f"FALKON-BLESS fit: M = {model.centers.shape[0]} centers "
          f"in {time.perf_counter() - t0:.1f}s (backend={model.backend.name})")

    # --- bursty traffic: variable-size requests from the same distribution --
    # (KrrServer accepts the fitted estimator directly)
    server = KrrServer(est, backend=backend, max_wave=2048, min_bucket=64)
    kq = jax.random.PRNGKey(2)
    sizes = [int(s) for s in jax.random.randint(kq, (args.requests,), 1, 65)]
    reqs = []
    for i, r in enumerate(sizes):
        kq, kr = jax.random.split(kq)
        qi = cl[i % 10] + 0.4 * jax.random.normal(kr, (r, d))
        reqs.append(qi)

    for q in reqs:  # warmup: replay the trace once so every wave bucket the
        server.submit(q)  # timed run hits is already compiled
    server.flush()
    server.reset()  # zero the stats for the timed run

    t0 = time.perf_counter()
    rids = [server.submit(q) for q in reqs]
    preds = server.flush()
    jax.block_until_ready(preds[rids[-1]])
    dt = time.perf_counter() - t0

    s = server.stats
    print(f"{s['requests']} requests / {s['rows']} rows in {dt * 1e3:.1f} ms "
          f"({s['rows'] / dt:.0f} rows/s)")
    print(f"{s['dispatches']} fused dispatches (vs {s['requests']} naive), "
          f"buckets {sorted(s['buckets'])}, "
          f"padding overhead {s['padded_rows'] / max(1, s['rows']):.1%}")

    # spot-check one response against the unbatched path
    err = float(jnp.max(jnp.abs(preds[rids[0]] - model.predict(reqs[0]))))
    print(f"batched vs direct max abs diff: {err:.2e}")


if __name__ == "__main__":
    main()
