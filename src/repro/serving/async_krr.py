"""Fault-tolerant async continuous-batching KRR serving (DESIGN.md §9).

``KrrServer`` (serving/krr.py) packs requests into padded pow2 waves but is
synchronous and fragile: ``flush`` blocks until everything is served, a
single bad wave poisons every co-packed request, and there is no notion of
queue pressure or deadlines. ``AsyncKrrServer`` keeps the wave packing and
bucket-bounded jit cache and wraps them in a serving loop with explicit
failure domains:

  * **Bounded queue + backpressure.** ``submit`` rejects (``QueueFull``) or
    sheds the oldest queued request (policy ``overflow="shed_oldest"``)
    once ``max_queue_rows`` is exceeded — overload degrades tail latency,
    never memory.
  * **Per-request deadlines.** A request whose deadline passes while still
    queued is EXPIRED at pack time instead of wasting a dispatch slot.
  * **Slot recycling.** Up to ``max_inflight`` waves are in flight at once
    (JAX async dispatch): ``step()`` first fills free slots from the queue,
    then completes the oldest wave — the device pipeline stays busy while
    the host packs, exactly the ServeEngine fixed-slot discipline applied
    to wave-granular work.
  * **Wave-level failure isolation.** A wave that fails (dispatch error or
    non-finite outputs caught by the §9 fence) is retried split in half,
    recursively; a singleton that still fails marks only *that* request
    FAILED. One poisoned request costs log2(wave) extra dispatches, not the
    wave.
  * **Graceful degradation.** When the rolling p99 wave latency breaches
    ``slo``, the server switches to ``fallback_model`` (e.g. a coarser
    center set) until p99 recovers below ``recover_factor * slo``
    (hysteresis, so it doesn't flap).
  * **Zero-downtime model swaps.** ``swap_model`` replaces the served
    model under live traffic, atomic at wave granularity behind a pre-swap
    health probe; swap provenance (swaps / swaps_rejected / model_version /
    last_swap) lands in ``stats`` and every request is tagged with the
    generation that served it (DESIGN.md §11).

Deterministic tests drive this with ``repro.testing.faults`` (injected NaN
tiles / latency) and ``VirtualClock`` via the ``clock=`` hook.

    server = AsyncKrrServer(model, config=ServeConfig(slo=0.05))
    rid = server.submit(x_req, deadline=clock() + 0.2)
    server.run_until_idle()
    server.result(rid)        # Array | None; server.status(rid) says why
"""
from __future__ import annotations

import collections
import dataclasses
import enum
import time
from typing import Callable, Deque, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core import health
from ..core.falkon import FalkonModel
from ..core.gram import BackendLike
from .krr import pow2_bucket, probe_model

Array = jax.Array

#: swap_model sentinel: "leave the fallback model alone" (None is a real
#: value — it clears the fallback).
_KEEP = object()


class QueueFull(RuntimeError):
    """Raised by ``submit`` when the queue is full and ``overflow="reject"``."""


class RequestStatus(enum.Enum):
    """Lifecycle of a request inside ``AsyncKrrServer``."""

    QUEUED = "queued"
    IN_FLIGHT = "in_flight"
    DONE = "done"
    FAILED = "failed"
    EXPIRED = "expired"
    SHED = "shed"


@dataclasses.dataclass
class Request:
    """One queued prediction request and its serving metadata."""

    rid: int
    x: Array
    submitted: float
    deadline: Optional[float] = None
    status: RequestStatus = RequestStatus.QUEUED
    result: Optional[Array] = None
    error: Optional[str] = None
    #: stats["model_version"] at dispatch time — which model generation
    #: served this request (None until dispatched). Chaos tests use it to
    #: prove swap atomicity: every DONE result matches exactly the tagged
    #: generation's predictions, never a mix.
    model_version: Optional[int] = None


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Serving-loop policy knobs for ``AsyncKrrServer``.

    Attributes:
      max_wave: row budget per fused dispatch (requests never split).
      min_bucket: smallest pow2 padding bucket (bounds the jit cache).
      max_queue_rows: queued-row bound; None = unbounded (no backpressure).
      overflow: what ``submit`` does at the bound — ``"reject"`` raises
        ``QueueFull``; ``"shed_oldest"`` drops the oldest queued request
        (marked SHED) to admit the new one.
      deadline: default per-request deadline in seconds after submit
        (None = no deadline); ``submit(deadline=...)`` overrides with an
        absolute clock time.
      slo: target p99 wave latency in seconds; breaching it switches to the
        fallback model when one is configured (None disables).
      slo_window: rolling window of wave latencies for the p99 estimate.
      recover_factor: leave degraded mode when p99 < recover_factor * slo.
      check_finite: fence every completed wave's outputs; non-finite rows
        trigger the split-retry isolation path instead of reaching clients.
      max_inflight: wave slots kept in flight before completion is forced.
    """

    max_wave: int = 4096
    min_bucket: int = 64
    max_queue_rows: Optional[int] = None
    overflow: str = "reject"
    deadline: Optional[float] = None
    slo: Optional[float] = None
    slo_window: int = 64
    recover_factor: float = 0.5
    check_finite: bool = True
    max_inflight: int = 2

    def __post_init__(self):
        if self.max_wave < 1 or self.min_bucket < 1 or self.max_inflight < 1:
            raise ValueError("max_wave, min_bucket, max_inflight must be positive")
        if self.overflow not in ("reject", "shed_oldest"):
            raise ValueError(f"overflow must be 'reject' or 'shed_oldest', "
                             f"got {self.overflow!r}")
        if not 0.0 < self.recover_factor <= 1.0:
            raise ValueError("recover_factor must be in (0, 1]")


@dataclasses.dataclass
class _Wave:
    """One in-flight dispatch: its requests and the (padded) prediction."""

    requests: List[Request]
    rows: int
    pred: Optional[Array]
    started: float
    degraded: bool
    version: int = 0  # model generation this wave was packed against


def _unwrap(model) -> FalkonModel:
    """Accept a FalkonModel or a fitted repro.api estimator."""
    if hasattr(model, "centers"):
        return model
    inner = getattr(model, "model_", None)
    if inner is None:
        raise ValueError(f"{type(model).__name__} has no fitted model; "
                         "call .fit before serving it")
    return inner


class AsyncKrrServer:
    """Fault-tolerant continuous-batching server over one (or two) models.

    Args:
      model: primary ``FalkonModel`` or fitted ``repro.api`` estimator.
      fallback_model: cheaper model served while degraded (optional).
      config: the ``ServeConfig`` policy bundle.
      backend: per-server override of the model's fit-time backend.
      clock: monotonic-seconds callable; inject ``VirtualClock`` in tests.
    """

    def __init__(self, model, *, fallback_model=None,
                 config: ServeConfig = ServeConfig(),
                 backend: BackendLike = None,
                 clock: Callable[[], float] = time.monotonic):
        self.model = _unwrap(model)
        self.fallback_model = (None if fallback_model is None
                               else _unwrap(fallback_model))
        d = self.model.centers.shape[1]
        if self.fallback_model is not None and \
                self.fallback_model.centers.shape[1] != d:
            raise ValueError("fallback model feature dim "
                             f"{self.fallback_model.centers.shape[1]} != {d}")
        self.config = config
        self.backend = backend
        self.clock = clock
        self.degraded = False
        self._queue: Deque[Request] = collections.deque()
        self._queued_rows = 0
        self._inflight: Deque[_Wave] = collections.deque()
        self._requests: Dict[int, Request] = {}
        self._next_rid = 0
        self._latencies: Deque[float] = collections.deque(maxlen=config.slo_window)
        # Model provenance (DESIGN.md §11): swaps / swaps_rejected count
        # accepted and probe-rejected swap_model calls, model_version is the
        # current generation (0 = construction-time model; every dispatched
        # wave and request is tagged with it), last_swap the clock time of
        # the latest accepted swap (None = never) — model age is
        # clock() - last_swap.
        self.stats = {"requests": 0, "rows": 0, "dispatches": 0,
                      "padded_rows": 0, "buckets": set(), "wave_failures": 0,
                      "splits": 0, "shed": 0, "expired": 0, "failed": 0,
                      "degraded_waves": 0, "swaps": 0, "swaps_rejected": 0,
                      "model_version": 0, "last_swap": None}

    # -- intake --------------------------------------------------------------

    def submit(self, x: Array, *, deadline: Optional[float] = None) -> int:
        """Queue a (r, d) request; returns its id.

        Raises ``ValueError`` on malformed or non-finite input (a NaN row
        must not reach a shared wave) and ``QueueFull`` under backpressure
        with the ``"reject"`` policy. ``deadline`` is an absolute clock
        time; defaults to ``config.deadline`` seconds from now.
        """
        x = jnp.asarray(x)
        d = self.model.centers.shape[1]
        if x.ndim != 2 or x.shape[0] == 0 or x.shape[1] != d:
            raise ValueError(f"request must be a non-empty (r, {d}) array, "
                             f"got {x.shape}")
        if x.shape[0] > self.config.max_wave:
            raise ValueError(f"request rows {x.shape[0]} exceed max_wave "
                             f"{self.config.max_wave}")
        if not bool(jnp.all(jnp.isfinite(x))):
            raise ValueError("request contains non-finite values; refusing "
                             "to pack it into a shared wave")
        cap = self.config.max_queue_rows
        if cap is not None:
            while self._queued_rows + x.shape[0] > cap:
                if self.config.overflow == "reject" or not self._queue:
                    raise QueueFull(
                        f"queue at {self._queued_rows} rows (cap {cap})")
                victim = self._queue.popleft()
                self._queued_rows -= victim.x.shape[0]
                victim.status = RequestStatus.SHED
                victim.error = "shed under queue pressure"
                self.stats["shed"] += 1
        now = self.clock()
        if deadline is None and self.config.deadline is not None:
            deadline = now + self.config.deadline
        req = Request(rid=self._next_rid, x=x, submitted=now, deadline=deadline)
        self._next_rid += 1
        self._queue.append(req)
        self._queued_rows += x.shape[0]
        self._requests[req.rid] = req
        self.stats["requests"] += 1
        self.stats["rows"] += x.shape[0]
        return req.rid

    # -- model lifecycle -----------------------------------------------------

    def swap_model(self, model, *, fallback_model=_KEEP,
                   probe_x: Optional[Array] = None) -> bool:
        """Zero-downtime model swap, atomic at wave granularity.

        The model is read once per wave at dispatch time, so the swap needs
        no locking or draining: waves already in flight complete on the old
        model, every wave packed after this call predicts with the new one,
        and no wave ever mixes the two. Queued (not yet dispatched)
        requests route to the new model — they have not been predicted yet.

        The candidate first passes the ``probe_model`` health fence (finite
        alpha + finite predictions on ``probe_x``, defaulting to the
        candidate's own centers). A poisoned candidate is REJECTED — the
        method returns False, ``stats["swaps_rejected"]`` increments, the
        incumbent keeps serving, and the fallback/degradation machinery is
        untouched — so a bad refit can never take down clean traffic.

        On success: ``stats`` gains the provenance (``swaps`` increments,
        ``model_version`` bumps, ``last_swap`` = now) and True is returned.
        ``fallback_model`` optionally replaces the degraded-mode model in
        the same call (None clears it); omitted = kept. ``ValueError``
        (unfitted estimator, feature-dim mismatch) propagates — caller
        bugs are not "rejections".
        """
        try:
            mdl = probe_model(model, probe_x, backend=self.backend)
        except ValueError:
            raise
        except Exception as e:  # noqa: BLE001 — a failing probe IS the signal
            self.stats["swaps_rejected"] += 1
            health.record_event("swap_rejected", error=repr(e))
            return False
        d = self.model.centers.shape[1]
        if mdl.centers.shape[1] != d:
            raise ValueError(f"swap candidate feature dim "
                             f"{mdl.centers.shape[1]} != {d}")
        if fallback_model is not _KEEP:
            fb = None if fallback_model is None else _unwrap(fallback_model)
            if fb is not None and fb.centers.shape[1] != d:
                raise ValueError(f"fallback model feature dim "
                                 f"{fb.centers.shape[1]} != {d}")
            self.fallback_model = fb
        self.model = mdl
        self.stats["swaps"] += 1
        self.stats["model_version"] += 1
        self.stats["last_swap"] = float(self.clock())
        health.record_event("model_swap",
                            version=self.stats["model_version"])
        return True

    # -- serving loop --------------------------------------------------------

    def step(self) -> bool:
        """One scheduler tick: fill free wave slots, then complete the
        oldest in-flight wave. Returns True if any work remains."""
        while self._queue and len(self._inflight) < self.config.max_inflight:
            if not self._dispatch_next():
                break
        if self._inflight:
            self._complete_oldest()
        return bool(self._queue or self._inflight)

    def run_until_idle(self) -> None:
        """Drive ``step`` until the queue and all wave slots are empty."""
        while self.step():
            pass

    def result(self, rid: int) -> Optional[Array]:
        """The (r,) / (r, k) prediction for ``rid``, or None if not DONE."""
        return self._requests[rid].result

    def status(self, rid: int) -> RequestStatus:
        """Lifecycle state of ``rid`` (why ``result`` may be None)."""
        return self._requests[rid].status

    def p99_latency(self) -> Optional[float]:
        """Rolling p99 of wave latencies (None until a wave completed)."""
        if not self._latencies:
            return None
        return float(np.percentile(np.asarray(self._latencies), 99))

    # -- internals -----------------------------------------------------------

    def _pack(self) -> List[Request]:
        """Pop a wave's worth of live requests (expiring stale ones)."""
        now = self.clock()
        wave: List[Request] = []
        rows = 0
        while self._queue:
            nxt = self._queue[0]
            if nxt.deadline is not None and now > nxt.deadline:
                self._queue.popleft()
                self._queued_rows -= nxt.x.shape[0]
                nxt.status = RequestStatus.EXPIRED
                nxt.error = "deadline passed while queued"
                self.stats["expired"] += 1
                continue
            if wave and rows + nxt.x.shape[0] > self.config.max_wave:
                break
            self._queue.popleft()
            self._queued_rows -= nxt.x.shape[0]
            wave.append(nxt)
            rows += nxt.x.shape[0]
        return wave

    def _serving_model(self) -> FalkonModel:
        if self.degraded and self.fallback_model is not None:
            return self.fallback_model
        return self.model

    def _dispatch_next(self) -> bool:
        """Pack and dispatch one wave; False if the queue yielded nothing."""
        wave = self._pack()
        if not wave:
            return False
        self._dispatch(wave)
        return True

    def _dispatch(self, wave: List[Request]) -> bool:
        """Dispatch one wave. True if it went in flight; False if dispatch
        itself raised (the failure was already isolated via _wave_failed)."""
        rows = sum(r.x.shape[0] for r in wave)
        xw = wave[0].x if len(wave) == 1 else jnp.concatenate(
            [r.x for r in wave], axis=0)
        bucket = pow2_bucket(rows, self.config.min_bucket)
        xp = jnp.pad(xw, ((0, bucket - rows), (0, 0)))
        model = self._serving_model()
        degraded = model is not self.model
        started = self.clock()
        self.stats["dispatches"] += 1
        self.stats["padded_rows"] += bucket - rows
        self.stats["buckets"].add(bucket)
        if degraded:
            self.stats["degraded_waves"] += 1
        version = self.stats["model_version"]
        for r in wave:
            r.status = RequestStatus.IN_FLIGHT
            # tagged at dispatch — the whole wave shares one model, so a
            # swap between waves can never split a wave across generations.
            r.model_version = version
        # predict is async-dispatched: the host returns with a future-backed
        # Array and keeps packing while the device (or injected fault) runs.
        # An *eager* dispatch failure (e.g. a kernel raising at launch) is a
        # wave failure like any other and goes through the same isolation.
        try:
            pred = model.predict(xp, backend=self.backend)
        except Exception as e:  # noqa: BLE001 — isolated, never propagated
            self._wave_failed(_Wave(requests=wave, rows=rows, pred=None,
                                    started=started, degraded=degraded,
                                    version=version), e)
            return False
        self._inflight.append(_Wave(requests=wave, rows=rows, pred=pred,
                                    started=started, degraded=degraded,
                                    version=version))
        return True

    def _complete_oldest(self) -> None:
        """Block on the oldest in-flight wave (FIFO completion)."""
        self._complete(self._inflight.popleft())

    def _complete(self, wave: _Wave) -> None:
        """Block on a wave; scatter results or isolate the failure."""
        try:
            pred = jax.block_until_ready(wave.pred)
            if self.config.check_finite:
                live = pred[:wave.rows]
                if not bool(jnp.all(jnp.isfinite(live))):
                    raise health.NonFiniteError(
                        f"wave of {len(wave.requests)} requests produced "
                        f"{int(jnp.sum(~jnp.isfinite(live)))} non-finite "
                        "outputs")
        except Exception as e:  # noqa: BLE001 — any wave failure is isolated
            self._wave_failed(wave, e)
            return
        latency = self.clock() - wave.started
        off = 0
        for r in wave.requests:
            r.result = pred[off:off + r.x.shape[0]]
            off += r.x.shape[0]
            r.status = RequestStatus.DONE
        self._latencies.append(latency)
        self._update_slo()

    def _wave_failed(self, wave: _Wave, err: Exception) -> None:
        """Isolate a failed wave: retry split in half, recursively; a
        singleton that still fails takes down only its own request."""
        self.stats["wave_failures"] += 1
        health.record_event("wave_failure", requests=len(wave.requests),
                            rows=wave.rows, error=repr(err))
        if len(wave.requests) == 1:
            req = wave.requests[0]
            req.status = RequestStatus.FAILED
            req.error = repr(err)
            self.stats["failed"] += 1
            return
        mid = len(wave.requests) // 2
        self.stats["splits"] += 1
        for half in (wave.requests[:mid], wave.requests[mid:]):
            # complete the retry immediately (pop() = the wave _dispatch just
            # appended, NOT the FIFO head — older unrelated waves stay put):
            # retries are synchronous so a persistent fault bottoms out to
            # singletons before new traffic packs in.
            if self._dispatch(half):
                self._complete(self._inflight.pop())

    def _update_slo(self) -> None:
        cfg = self.config
        if cfg.slo is None or self.fallback_model is None:
            return
        p99 = self.p99_latency()
        if p99 is None:
            return
        if not self.degraded and p99 > cfg.slo:
            self.degraded = True
            health.record_event("slo_degrade", p99=p99, slo=cfg.slo)
        elif self.degraded and p99 < cfg.recover_factor * cfg.slo:
            self.degraded = False
            health.record_event("slo_recover", p99=p99, slo=cfg.slo)


__all__ = ["AsyncKrrServer", "ServeConfig", "Request", "RequestStatus",
           "QueueFull"]
