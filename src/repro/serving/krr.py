"""Serving-grade batched KRR prediction — micro-batching over
``FalkonModel.predict`` with padded pow2 row buckets (DESIGN.md §4).

The heavy-traffic scenario: many concurrent clients each submit a handful of
query points. Dispatching per request wastes the accelerator (one launch and
one sub-tile Gram block per request) and, worse, every distinct request size
is a fresh jit shape — unbounded clients means an unbounded compile cache.

``KrrServer`` fixes both: pending requests are packed into *waves* of at
most ``max_wave`` rows, each wave is zero-padded up to a power-of-two row
bucket (never below ``min_bucket``), and one fused ``knm_matvec`` dispatch
through the kernel-operator ``Backend`` seam serves the whole wave. The jit
cache then holds at most ``log2(max_wave / min_bucket) + 1`` executables per
model, independent of traffic.

``model`` accepts either a raw ``FalkonModel`` or any fitted ``repro.api``
estimator (``FalkonRegressor`` / ``NystromRegressor`` / ``ExactKrr`` — the
fitted ``model_`` is unwrapped). Multi-output models serve (r, k) blocks per
request through the same wave packing; since the multi-RHS panel contraction
(DESIGN.md §2.4) a k-output wave costs ONE fused ``knm_matvec`` with the
(M, k) alpha panel — one kernel evaluation per wave regardless of k.

    server = KrrServer(FalkonRegressor(...).fit(x, y))
    rid = server.submit(x_req)        # queue a (r, d) request
    preds = server.flush()            # {rid: (r,) or (r, k) predictions}
    server.predict(x)                 # submit + flush convenience
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Callable, Deque, Dict, List, Tuple, Union

import jax
import jax.numpy as jnp

from ..core import health
from ..core.falkon import FalkonModel
from ..core.gram import BackendLike

Array = jax.Array


def pow2_bucket(rows: int, min_bucket: int) -> int:
    """Smallest power-of-two >= rows, floored at min_bucket."""
    return max(min_bucket, 1 << max(0, rows - 1).bit_length())


def probe_model(model, probe_x: Array | None = None, *,
                backend: BackendLike = None) -> FalkonModel:
    """Pre-swap health probe (DESIGN.md §11): returns the unwrapped model
    or raises.

    Two fences, in order: the candidate's alpha must be finite
    (``health.NonFiniteError`` otherwise — a diverged refit), and its
    predictions on a probe batch must be finite. ``probe_x`` defaults to a
    prefix of the candidate's own centers — rows guaranteed in-distribution
    for any model, so a probe failure always indicts the model, never the
    probe. A candidate that cannot predict finitely on its own centers must
    not reach live traffic.

    Raises ``ValueError`` on an unfitted estimator (a programming error,
    not a poisoned model — callers should not swallow it).
    """
    mdl = model if hasattr(model, "centers") else getattr(model, "model_", None)
    if mdl is None:
        raise ValueError(f"{type(model).__name__} has no fitted model; "
                         "call .fit before swapping it in")
    health.check_finite(mdl.alpha, "swap candidate alpha")
    if probe_x is None:
        probe_x = mdl.centers[: min(8, mdl.centers.shape[0])]
    pred = mdl.predict(jnp.asarray(probe_x), backend=backend)
    health.check_finite(pred, "swap candidate probe predictions")
    return mdl


@dataclasses.dataclass
class KrrServer:
    """Micro-batching front end over one FALKON/KRR model.

    Attributes:
      model: a ``FalkonModel`` or a fitted ``repro.api`` estimator (its
        ``model_`` is unwrapped); prediction runs through its backend seam.
      backend: per-server override of the model's fit-time backend.
      max_wave: row budget per fused dispatch — requests are packed into
        waves of at most this many rows (a single larger request still goes
        out alone, padded to its own pow2 bucket).
      min_bucket: smallest padded bucket; keeps tiny waves off sub-tile
        shapes and bounds the bucket count from below.
      clock: monotonic-seconds callable stamping swap provenance (inject
        ``VirtualClock`` in tests).

    Model-provenance stats (see DESIGN.md §11; NOTE ``reset()`` wipes them
    with the rest of the counters): ``swaps`` / ``swaps_rejected`` count
    accepted and probe-rejected ``swap_model`` calls, ``model_version``
    increments per accepted swap (0 = the construction-time model), and
    ``last_swap`` is the clock time of the latest accepted swap (None =
    never swapped) — model age is ``clock() - last_swap``.
    """

    model: Union[FalkonModel, object]  # object: any fitted repro.api estimator
    backend: BackendLike = None
    max_wave: int = 4096
    min_bucket: int = 64
    clock: Callable[[], float] = time.monotonic

    def __post_init__(self):
        if self.max_wave < 1 or self.min_bucket < 1:
            raise ValueError("max_wave and min_bucket must be positive")
        if not hasattr(self.model, "centers"):  # a repro.api estimator
            inner = getattr(self.model, "model_", None)
            if inner is None:
                raise ValueError(
                    f"{type(self.model).__name__} has no fitted model; "
                    "call .fit before serving it")
            self.model = inner
        self.reset()

    def reset(self) -> None:
        """Drop queued requests and zero the counters (e.g. after warmup)."""
        # deque: flush drains from the left, so popleft must be O(1) —
        # a list.pop(0) here made a full flush quadratic in queue length.
        self._queue: Deque[Tuple[int, Array]] = collections.deque()
        self._next_rid = 0
        self._pending_rows = 0
        # serving counters: dispatches vs requests is the batching win;
        # padded_rows / rows the padding overhead; buckets the jit-cache set;
        # swaps / swaps_rejected / model_version / last_swap the model
        # provenance (class docstring).
        self.stats = {"requests": 0, "rows": 0, "dispatches": 0,
                      "padded_rows": 0, "buckets": set(), "swaps": 0,
                      "swaps_rejected": 0, "model_version": 0,
                      "last_swap": None}

    def swap_model(self, model, *, probe_x: Array | None = None) -> bool:
        """Swap the served model after a ``probe_model`` health fence.

        Returns True on success (provenance stats updated), False if the
        probe rejected the candidate — the current model keeps serving, so
        a poisoned refit can never take down clean traffic. ``ValueError``
        (unfitted estimator, feature-dim mismatch) propagates: that is a
        caller bug, not a bad model.
        """
        try:
            mdl = probe_model(model, probe_x, backend=self.backend)
        except ValueError:
            raise
        except Exception as e:  # noqa: BLE001 — a failing probe IS the signal
            self.stats["swaps_rejected"] += 1
            health.record_event("swap_rejected", error=repr(e))
            return False
        d = self.model.centers.shape[1]
        if mdl.centers.shape[1] != d:
            raise ValueError(f"swap candidate feature dim "
                             f"{mdl.centers.shape[1]} != {d}")
        self.model = mdl
        self.stats["swaps"] += 1
        self.stats["model_version"] += 1
        self.stats["last_swap"] = float(self.clock())
        health.record_event("model_swap",
                            version=self.stats["model_version"])
        return True

    def submit(self, x: Array) -> int:
        """Queue a (r, d) request; returns its id (see flush)."""
        x = jnp.asarray(x)
        d = self.model.centers.shape[1]
        if x.ndim != 2 or x.shape[0] == 0 or x.shape[1] != d:
            raise ValueError(f"request must be a non-empty (r, {d}) array, got {x.shape}")
        # Finite-input fence (DESIGN.md §9): requests are concatenated into
        # shared waves, so one NaN row would contaminate every co-packed
        # request's Gram tile. Reject it at the door instead.
        if not bool(jnp.all(jnp.isfinite(x))):
            raise ValueError(
                f"request contains non-finite values "
                f"({int(jnp.sum(~jnp.isfinite(x)))} of {x.size}); refusing to "
                "pack it into a shared wave")
        rid = self._next_rid
        self._next_rid += 1
        self._queue.append((rid, x))
        self._pending_rows += x.shape[0]
        self.stats["requests"] += 1
        self.stats["rows"] += x.shape[0]
        return rid

    @property
    def pending_rows(self) -> int:
        return self._pending_rows

    def flush(self) -> Dict[int, Array]:
        """Serve every queued request; returns {request id: (r,) predictions}."""
        out: Dict[int, Array] = {}
        while self._queue:
            wave: List[Tuple[int, Array]] = [self._queue.popleft()]
            rows = wave[0][1].shape[0]
            # pack until the row budget: a request never splits across waves
            while self._queue and rows + self._queue[0][1].shape[0] <= self.max_wave:
                rid, x = self._queue.popleft()
                wave.append((rid, x))
                rows += x.shape[0]
            self._pending_rows -= rows
            xw = wave[0][1] if len(wave) == 1 else jnp.concatenate(
                [x for _, x in wave], axis=0)
            bucket = pow2_bucket(rows, self.min_bucket)
            xp = jnp.pad(xw, ((0, bucket - rows), (0, 0)))
            pred = self.model.predict(xp, backend=self.backend)
            self.stats["dispatches"] += 1
            self.stats["padded_rows"] += bucket - rows
            self.stats["buckets"].add(bucket)
            off = 0
            for rid, x in wave:
                out[rid] = pred[off:off + x.shape[0]]
                off += x.shape[0]
        return out

    def predict(self, x: Array) -> Array:
        """One-shot convenience: submit + flush a single request.

        Still bucket-padded, so ad-hoc callers share the serving jit cache.
        """
        rid = self.submit(x)
        return self.flush()[rid]
