"""Batched serving: prefill + continuous-batching decode with KV/SSM caches.

``prefill_logits`` is the parallel prompt forward the prefill_* dry-run
shapes lower. ``ServeEngine`` is a minimal continuous-batching loop: fixed
B slots with *per-slot* positions/lengths (decode_step accepts (B,)
positions and writes each slot's KV row independently), greedy sampling,
slot recycling on completion. examples/serve_batched.py drives it.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from ..models import decode_step, forward, init_cache, logits_fn, padded_vocab
from ..models.config import ArchConfig

Array = jax.Array


def sample_greedy(logits: Array, vocab_size: int) -> Array:
    masked = jnp.where(jnp.arange(logits.shape[-1]) < vocab_size, logits, -jnp.inf)
    return jnp.argmax(masked, axis=-1).astype(jnp.int32)


def prefill(params: dict, cfg: ArchConfig, tokens: Array, cache_len: int) -> tuple[Array, dict]:
    """Sequential prompt pass populating the decode cache for every mixer
    type (KV rows for attention layers, conv/SSD state for mamba layers).
    Returns (last-token logits (B, Vp), cache)."""
    b, s = tokens.shape
    cache = init_cache(cfg, b, cache_len)
    vp = padded_vocab(cfg)

    def step(carry, t):
        cache, _ = carry
        logits, cache = decode_step(params, cfg, cache, tokens[:, t], t, length=t + 1)
        return (cache, logits.astype(jnp.float32)), None

    (cache, logits), _ = jax.lax.scan(
        step, (cache, jnp.zeros((b, vp), jnp.float32)), jnp.arange(s))
    return logits, cache


def prefill_logits(params: dict, cfg: ArchConfig, batch: dict) -> Array:
    """Parallel prompt forward -> last-position logits (dry-run path)."""
    h = forward(params, cfg, batch)
    return logits_fn(params, cfg, h[:, -1])


@dataclasses.dataclass
class ServeEngine:
    """Continuous batching over fixed slots."""

    params: dict
    cfg: ArchConfig
    max_len: int
    batch_slots: int

    def __post_init__(self):
        self.cache = init_cache(self.cfg, self.batch_slots, self.max_len)
        self.pos = jnp.zeros((self.batch_slots,), jnp.int32)  # next write index
        self.tokens = jnp.zeros((self.batch_slots,), jnp.int32)
        self.active = jnp.zeros((self.batch_slots,), bool)
        self.outputs: list[list[int]] = [[] for _ in range(self.batch_slots)]
        self._step = jax.jit(
            lambda p, c, t, pos, ln: decode_step(p, self.cfg, c, t, pos, length=ln))

    def add_request(self, slot: int, prompt: list[int]) -> None:
        """Feed a prompt through the decode path into this slot's cache.

        The prompt must be non-empty: the first sampled token comes from the
        last prompt position's logits, so an empty prompt has nothing to
        condition on (and previously surfaced as an unbound-variable error).
        """
        if not prompt:
            raise ValueError(
                f"add_request(slot={slot}): prompt must contain at least one "
                "token — an empty prompt has no logits to sample from")
        for tok in prompt:
            toks = self.tokens.at[slot].set(tok)
            logits, self.cache = self._step(self.params, self.cache, toks,
                                            self.pos, self.pos + 1)
            self.pos = self.pos.at[slot].add(1)
        self.tokens = self.tokens.at[slot].set(
            int(sample_greedy(logits[slot], self.cfg.vocab_size)))
        self.active = self.active.at[slot].set(True)
        self.outputs[slot] = [int(self.tokens[slot])]

    def step(self) -> Array:
        """One decode step for all slots (inactive slots decode garbage that
        is simply not recorded — the standard padded-slot trick)."""
        logits, self.cache = self._step(self.params, self.cache, self.tokens,
                                        self.pos, self.pos + 1)
        nxt = sample_greedy(logits, self.cfg.vocab_size)
        self.pos = self.pos + self.active.astype(jnp.int32)
        self.tokens = jnp.where(self.active, nxt, self.tokens)
        for i in range(self.batch_slots):
            if bool(self.active[i]):
                self.outputs[i].append(int(nxt[i]))
        return nxt

    def finish(self, slot: int) -> list[int]:
        self.active = self.active.at[slot].set(False)
        return self.outputs[slot]
