from .engine import ServeEngine, prefill, sample_greedy

__all__ = ["ServeEngine", "prefill", "sample_greedy"]
