from .async_krr import (AsyncKrrServer, QueueFull, RequestStatus, ServeConfig)
from .engine import ServeEngine, prefill, sample_greedy
from .krr import KrrServer, pow2_bucket, probe_model

__all__ = ["ServeEngine", "prefill", "sample_greedy", "KrrServer",
           "pow2_bucket", "probe_model", "AsyncKrrServer", "ServeConfig",
           "RequestStatus", "QueueFull"]
