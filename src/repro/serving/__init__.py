from .async_krr import (AsyncKrrServer, QueueFull, RequestStatus, ServeConfig)
from .engine import ServeEngine, prefill, sample_greedy
from .krr import KrrServer, pow2_bucket

__all__ = ["ServeEngine", "prefill", "sample_greedy", "KrrServer",
           "pow2_bucket", "AsyncKrrServer", "ServeConfig", "RequestStatus",
           "QueueFull"]
