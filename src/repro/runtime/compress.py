"""Gradient compression for the slow (cross-pod DCI) all-reduce.

Two levels:
  * bf16 all-reduce: cast-psum-cast. Free 2x over fp32 with negligible
    quality impact at pod counts <= 8 (loss-scale safe: grads are already
    unit-ish post-clip).
  * int8 + error feedback: per-tensor symmetric quantization with a local
    residual carried between steps (1-bit-Adam-style EF). 4x over fp32.

Both operate on the grads pytree *before* the optimizer; inside pjit the
psum over 'pod' is expressed by the partitioner, so compression is applied
around the explicit shard_map collective in the pipeline-parallel path and
around host-level cross-pod reduction in the launcher.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp


def compressed_psum_bf16(tree: Any, axis: str) -> Any:
    return jax.tree.map(
        lambda g: jax.lax.psum(g.astype(jnp.bfloat16), axis).astype(g.dtype), tree)


def int8_compress(g: jax.Array, err: jax.Array) -> tuple[jax.Array, jax.Array, jax.Array]:
    """g + carried error -> (q int8, scale, new_error)."""
    gf = g.astype(jnp.float32) + err
    scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    new_err = gf - q.astype(jnp.float32) * scale
    return q, scale, new_err


def int8_decompress(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def ef_state_init(grads: Any) -> Any:
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def compressed_allreduce_int8(tree: Any, ef: Any, axis: str) -> tuple[Any, Any]:
    """Error-feedback int8 all-reduce over a shard_map axis."""

    def one(g, e):
        q, scale, new_e = int8_compress(g, e)
        # sum of dequantized contributions; scale is per-shard so psum the
        # dequantized tensor (wire format int8 + f32 scale per tensor)
        summed = jax.lax.psum(int8_decompress(q, scale), axis)
        return summed.astype(g.dtype), new_e

    pairs = jax.tree.map(one, tree, ef)
    out = jax.tree.map(lambda t: t[0], pairs, is_leaf=lambda t: isinstance(t, tuple))
    new_ef = jax.tree.map(lambda t: t[1], pairs, is_leaf=lambda t: isinstance(t, tuple))
    return out, new_ef
