"""Fault tolerance & straggler mitigation for the training loop.

HeartbeatMonitor: per-step wall-time tracking; a step slower than
``threshold x`` the running median flags a straggler (at real multi-pod
scale the hook triggers data-bucket redistribution / hot-spare swap; here
it is surfaced to the loop + logs, and is unit-tested with injected delays).

FaultTolerantLoop: checkpoint-restart supervision around a step function —
catches worker exceptions, restores the latest checkpoint, replays the
deterministic data pipeline from the restored step (data needs no state:
batches are a pure function of step), and resumes. Also hosts the elastic
path: on `rescale(n)`, the same checkpoint is restored under a new mesh via
checkpoint.restore_checkpoint(shardings=...).
"""
from __future__ import annotations

import dataclasses
import logging
import statistics
import time
from typing import Any, Callable, Optional

log = logging.getLogger("repro.runtime")


@dataclasses.dataclass
class HeartbeatMonitor:
    threshold: float = 2.5
    window: int = 32
    _durations: list[float] = dataclasses.field(default_factory=list)
    stragglers: list[tuple[int, float]] = dataclasses.field(default_factory=list)

    def record(self, step: int, duration: float) -> bool:
        """Returns True if this step is a straggler."""
        hist = self._durations[-self.window:]
        self._durations.append(duration)
        if len(hist) < 8:
            return False
        med = statistics.median(hist)
        if duration > self.threshold * med:
            self.stragglers.append((step, duration))
            log.warning("straggler: step %d took %.3fs (median %.3fs)", step, duration, med)
            return True
        return False

    @property
    def median(self) -> float:
        return statistics.median(self._durations) if self._durations else 0.0


class FaultTolerantLoop:
    """Supervised train loop: step -> heartbeat -> periodic async checkpoint;
    on failure restore + replay. ``failure_injector`` lets tests kill steps."""

    def __init__(self, step_fn: Callable[[Any, int], tuple[Any, dict]],
                 checkpointer, *, ckpt_every: int = 50,
                 monitor: Optional[HeartbeatMonitor] = None,
                 max_restarts: int = 3,
                 failure_injector: Optional[Callable[[int], None]] = None):
        self.step_fn = step_fn
        self.checkpointer = checkpointer
        self.ckpt_every = ckpt_every
        self.monitor = monitor or HeartbeatMonitor()
        self.max_restarts = max_restarts
        self.failure_injector = failure_injector
        self.restarts = 0

    def run(self, state: Any, start_step: int, num_steps: int,
            restore_fn: Callable[[], tuple[int, Any]]) -> tuple[Any, int]:
        step = start_step
        while step < start_step + num_steps:
            try:
                t0 = time.perf_counter()
                if self.failure_injector is not None:
                    self.failure_injector(step)
                state, metrics = self.step_fn(state, step)
                self.monitor.record(step, time.perf_counter() - t0)
                step += 1
                if step % self.ckpt_every == 0:
                    self.checkpointer.save(step, state)
            except Exception as e:  # noqa: BLE001 — supervision boundary
                self.restarts += 1
                if self.restarts > self.max_restarts:
                    raise
                log.warning("step %d failed (%s); restoring latest checkpoint", step, e)
                step, state = restore_fn()
        self.checkpointer.save(step, state)
        self.checkpointer.wait()
        return state, step
