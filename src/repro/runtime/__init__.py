from .compress import compressed_psum_bf16, int8_compress, int8_decompress
from .monitor import FaultTolerantLoop, HeartbeatMonitor

__all__ = ["compressed_psum_bf16", "int8_compress", "int8_decompress",
           "FaultTolerantLoop", "HeartbeatMonitor"]
