"""granite-moe-3b-a800m [moe] — 40 experts top-8, GQA kv=8.
[hf:ibm-granite/granite-3.0-3b-a800m-base]"""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="granite-moe-3b-a800m", family="moe",
    n_layers=32, d_model=1536, n_heads=24, n_kv_heads=8, head_dim=64,
    d_ff=512, vocab_size=49155,
    n_experts=40, top_k=8, moe_period=1,
    tie_embeddings=True,
)
