"""Architecture registry: ``--arch <id>`` resolves here.

One module per assigned architecture (exact configs from the public pool)
plus the paper's own FALKON workloads. ``smoke(cfg)`` derives the reduced
same-family config used by per-arch CPU smoke tests.
"""
from __future__ import annotations

import dataclasses

from ..models.config import ArchConfig
from . import (gemma_2b, granite_moe_3b_a800m, hubert_xlarge, jamba_v0_1_52b,
               llama4_scout_17b_a16e, mamba2_370m, minicpm_2b, phi3_mini_3_8b,
               qwen2_vl_2b, qwen3_32b)

_REGISTRY: dict[str, ArchConfig] = {
    m.CONFIG.name: m.CONFIG
    for m in (mamba2_370m, llama4_scout_17b_a16e, granite_moe_3b_a800m, gemma_2b,
              minicpm_2b, phi3_mini_3_8b, qwen3_32b, qwen2_vl_2b, jamba_v0_1_52b,
              hubert_xlarge)
}


def get_config(name: str) -> ArchConfig:
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_archs() -> list[str]:
    return sorted(_REGISTRY)


def smoke(cfg: ArchConfig) -> ArchConfig:
    """Reduced same-family config: small width/depth, tiny vocab/experts."""
    few_layers = cfg.layer_period if cfg.layer_period > 1 else 2
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-smoke",
        n_layers=few_layers,
        d_model=128,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 4) if cfg.n_kv_heads < cfg.n_heads else 4,
        head_dim=32,
        d_ff=min(cfg.d_ff, 256) if cfg.d_ff else 0,
        vocab_size=512,
        n_experts=min(cfg.n_experts, 8) if cfg.n_experts else 0,
        top_k=min(cfg.top_k, 2) if cfg.top_k else 0,
        shared_expert_ff=128 if cfg.shared_expert_ff else 0,
        ssm_state=min(cfg.ssm_state, 16) if cfg.ssm_state else 0,
        ssm_headdim=32,
        extra_image_tokens=16 if cfg.extra_image_tokens else 0,
        nystrom_landmarks=min(cfg.nystrom_landmarks, 32),
        attn_chunk=64,
    )
