"""jamba-v0.1-52b [hybrid] — Mamba+attn 1:7 interleave, MoE 16e top-2 every
other layer. [arXiv:2403.19887]"""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="jamba-v0.1-52b", family="hybrid",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14_336, vocab_size=65_536,
    n_experts=16, top_k=2, moe_period=2,
    attn_period=8, attn_offset=4,
    ssm_state=16, ssm_expand=2, ssm_conv=4, ssm_headdim=64,
)
