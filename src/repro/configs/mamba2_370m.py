"""mamba2-370m [ssm] — SSD, attention-free. [arXiv:2405.21060]"""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-370m", family="ssm",
    n_layers=48, d_model=1024, n_heads=1, n_kv_heads=1, head_dim=64,
    d_ff=0, vocab_size=50280,
    ssm_state=128, ssm_expand=2, ssm_conv=4, ssm_headdim=64,
    tie_embeddings=True,
)
