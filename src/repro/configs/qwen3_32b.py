"""qwen3-32b [dense] — qk_norm, GQA kv=8. [hf:Qwen/Qwen3-32B]"""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-32b", family="dense",
    n_layers=64, d_model=5120, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=25_600, vocab_size=151_936,
    qk_norm=True, rope_theta=1_000_000.0,
)
