"""llama4-scout-17b-16e [moe] — MoE 16e top-1 + shared expert, GQA kv=8.
[hf:meta-llama/Llama-4-Scout-17B-16E]"""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="llama4-scout-17b-a16e", family="moe",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, head_dim=128,
    d_ff=8192, vocab_size=202048,
    n_experts=16, top_k=1, moe_period=1, shared_expert_ff=8192,
    rope_theta=500_000.0,
)
