"""hubert-xlarge [audio] — encoder-only (w2v2 arch); conv frame frontend is a
stub per spec: inputs are precomputed frame embeddings. [arXiv:2106.07447]"""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="hubert-xlarge", family="audio",
    n_layers=48, d_model=1280, n_heads=16, n_kv_heads=16, head_dim=80,
    d_ff=5120, vocab_size=504,
    mlp_act="gelu", causal=False, has_decode=False, embed_inputs=False,
    pos="sinusoidal",
)
