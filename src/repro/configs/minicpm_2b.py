"""minicpm-2b [dense] — llama-like, MHA 36 heads, WSD schedule (optimizer).
[arXiv:2404.06395]"""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="minicpm-2b", family="dense",
    n_layers=40, d_model=2304, n_heads=36, n_kv_heads=36, head_dim=64,
    d_ff=5760, vocab_size=122_753,
    tie_embeddings=True,
)
