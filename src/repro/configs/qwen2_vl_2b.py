"""qwen2-vl-2b [vlm] — M-RoPE, dynamic-resolution vision (frontend stubbed:
input_specs provides precomputed patch embeddings). [arXiv:2409.12191]"""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-vl-2b", family="vlm",
    n_layers=28, d_model=1536, n_heads=12, n_kv_heads=2, head_dim=128,
    d_ff=8960, vocab_size=151_936,
    pos="mrope", mrope_sections=(16, 24, 24), rope_theta=1_000_000.0,
    extra_image_tokens=1024, tie_embeddings=True,
)
