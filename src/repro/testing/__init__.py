"""Test/chaos harnesses that ship with the package (see ``faults``)."""
from . import faults

__all__ = ["faults"]
