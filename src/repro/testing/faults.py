"""Named fault-injection points for the chaos suite (DESIGN.md §9).

Production modules host tiny hooks at their dispatch boundaries::

    if faults.active():                      # one dict emptiness check
        faults.raise_if("backend.error")
        out = faults.corrupt("gram.nan_tile", out)

With no fault armed, ``active()`` is a single module-level dict check —
the happy path pays nanoseconds per *host-level dispatch* (never per
element), which is what lets the hooks live in real code rather than a
test-only fork. This module deliberately imports nothing from ``repro``
so any layer can host a hook without an import cycle.

Injection points (the registry rejects unknown names):

  ``gram.nan_tile``     NaN written into a just-computed Gram/predict tile
                        (params: ``rows`` — how many leading rows to
                        poison, default 1).
  ``backend.error``     raise ``FaultInjected`` at kernel dispatch.
  ``dispatch.latency``  artificial per-dispatch latency (params:
                        ``seconds`` — float, or a callable
                        ``(rows, centers) -> float``; ``advance`` — a
                        virtual-clock hook called instead of sleeping).
  ``kmm.indefinite``    shift a K_MM-like matrix indefinite before its
                        factorization (params: ``shift`` — multiples of
                        the mean diagonal subtracted, default 2.0).
  ``ckpt.torn_write``   kill ``save_checkpoint`` mid-write — the hook fires
                        at every filesystem step (params: ``stage`` — fire
                        only at that named step, e.g. ``"pre_rename"`` =
                        the torn window between the complete temp dir and
                        the atomic rename; None = every step).
  ``online.corrupt_row``  poison a row of a batch appended to
                        ``OnlineFalkon`` with NaN (params: ``row`` — which
                        row, default 0) — upstream of the finite-input
                        fence, which must reject it.

Arming is scoped by the ``fault`` context manager; ``times=N`` makes a
fault fire on the first N hook hits then go inert (transient faults:
"the first wave fails, the retry succeeds"), and ``skip=K`` makes it sit
out the first K (matching) hits before firing — "kill at the K-th chunk
barrier" without counting from the call site. Hooks fire at *host dispatch
time*: jitted programs compiled before arming are cached and will not see
a fault baked in — the production hook sites are all eager for exactly
this reason, and chaos tests that touch traced paths clear jit caches.

``FaultyBackend`` wraps any kernel-operator backend with every hook, for
driving faults through code that takes a backend instance (e.g. proving
``GuardedBackend`` falls back). ``VirtualClock`` is a deterministic clock
for serving simulations (Poisson overload traces in virtual time).
"""
from __future__ import annotations

import contextlib
import dataclasses
import time
from typing import Any, Callable, Iterator

import jax.numpy as jnp

#: The known injection points; ``fault()`` rejects anything else so a typo
#: cannot silently arm nothing.
POINTS = frozenset({
    "gram.nan_tile",
    "backend.error",
    "dispatch.latency",
    "kmm.indefinite",
    "ckpt.torn_write",
    "online.corrupt_row",
})


class FaultInjected(RuntimeError):
    """The error raised by an armed ``backend.error`` injection point."""


@dataclasses.dataclass
class Fault:
    """One armed fault: its point, firing window, and parameters.

    ``seen`` counts every *matching* hook hit (after any ``stage`` filter),
    whether or not the fault fired — arming with ``times=0`` turns a fault
    into a pure hit counter, which is how the checkpoint crash-window test
    enumerates the filesystem steps of ``save_checkpoint``. ``skip`` holds
    the fault inert for the first ``skip`` matching hits.
    """

    point: str
    times: int | None = None  # fire at most N times; None = every hit
    skip: int = 0  # sit out the first K matching hits
    params: dict = dataclasses.field(default_factory=dict)
    fired: int = 0
    seen: int = 0

    @property
    def exhausted(self) -> bool:
        return self.times is not None and self.fired >= self.times


_ACTIVE: dict[str, Fault] = {}


def active() -> bool:
    """True iff any fault is armed — the happy-path fast check."""
    return bool(_ACTIVE)


@contextlib.contextmanager
def fault(point: str, *, times: int | None = None, skip: int = 0,
          **params: Any) -> Iterator[Fault]:
    """Arm ``point`` for the duration of the context; yields the Fault.

    ``times`` bounds how many hook hits fire (None = every hit); ``skip``
    holds the fault inert for the first K matching hits (fire *at* the
    K-th chunk/step, not the first); extra keyword arguments parameterize
    the point (see module docstring).
    """
    if point not in POINTS:
        raise ValueError(f"unknown fault point {point!r}; known: {sorted(POINTS)}")
    if point in _ACTIVE:
        raise RuntimeError(f"fault point {point!r} is already armed")
    f = Fault(point=point, times=times, skip=skip, params=params)
    _ACTIVE[point] = f
    try:
        yield f
    finally:
        _ACTIVE.pop(point, None)


def _take(point: str, tag: str | None = None) -> Fault | None:
    """Consume one firing of ``point`` if armed and inside its window.

    ``tag`` names the specific hook site (e.g. a ``save_checkpoint``
    filesystem step); a fault armed with a ``stage`` parameter matches only
    that tag, and only matching hits count against ``skip``/``times``.
    """
    if not _ACTIVE:
        return None
    f = _ACTIVE.get(point)
    if f is None:
        return None
    stage = f.params.get("stage")
    if stage is not None and tag is not None and stage != tag:
        return None
    f.seen += 1
    if f.seen <= f.skip or f.exhausted:
        return None
    f.fired += 1
    return f


# -- hook functions (called from production dispatch sites) -----------------


def raise_if(point: str = "backend.error", *, tag: str | None = None) -> None:
    """Raise ``FaultInjected`` if ``point`` is armed (dispatch-failure hook).

    ``tag`` names the hook site for stage-targeted faults (see ``_take``);
    the raised message carries both so chaos tests can assert *where* the
    simulated kill landed.
    """
    f = _take(point, tag)
    if f is not None:
        raise FaultInjected(
            f"injected fault at {point!r}"
            + (f" stage {tag!r}" if tag is not None else "")
            + f" (firing {f.fired})")


def sleep_if(point: str = "dispatch.latency", *, rows: int = 0, centers: int = 0) -> None:
    """Apply armed per-dispatch latency: real ``time.sleep`` or, when the
    fault carries an ``advance`` hook, a virtual-clock advance (keeps
    overload simulations deterministic and fast)."""
    f = _take(point)
    if f is None:
        return
    seconds = f.params.get("seconds", 0.0)
    if callable(seconds):
        seconds = seconds(rows, centers)
    advance = f.params.get("advance")
    if advance is not None:
        advance(seconds)
    elif seconds > 0:
        time.sleep(seconds)


def corrupt(point: str, x):
    """Return ``x`` corrupted per the armed fault at ``point`` (or as-is).

    ``gram.nan_tile`` poisons the first ``rows`` rows (default 1) of the
    tile with NaN; ``kmm.indefinite`` subtracts ``shift`` x the mean
    diagonal from the diagonal, pushing the matrix indefinite;
    ``online.corrupt_row`` sets row ``row`` (default 0) of an appended
    batch to NaN — bit rot on the ingest path, upstream of the fence.
    """
    f = _take(point)
    if f is None:
        return x
    if point == "gram.nan_tile":
        rows = int(f.params.get("rows", 1))
        return x.at[:rows].set(jnp.nan)
    if point == "kmm.indefinite":
        shift = float(f.params.get("shift", 2.0))
        scale = shift * jnp.mean(jnp.diagonal(x))
        return x - scale * jnp.eye(x.shape[0], dtype=x.dtype)
    if point == "online.corrupt_row":
        row = int(f.params.get("row", 0))
        return x.at[row].set(jnp.nan)
    raise ValueError(f"{point!r} is not a corruption point")


# ---------------------------------------------------------------------------
# Backend wrapper + virtual clock
# ---------------------------------------------------------------------------


class FaultyBackend:
    """A kernel-operator backend wrapper with every injection point armed.

    Duck-typed against the ``Backend`` seam (``jit_safe=False`` keeps all
    calls on the eager host path, where hooks fire reliably); unknown
    attributes delegate to the wrapped backend. Wrap a real backend and
    arm faults to drive failures through any code that accepts a backend
    instance — e.g. proving ``GuardedBackend(primary=FaultyBackend(...))``
    falls back per dispatch.
    """

    jit_safe = False
    name = "faulty"

    def __init__(self, inner):
        self.inner = inner

    def _pre(self, rows: int = 0, centers: int = 0) -> None:
        if active():
            sleep_if(rows=rows, centers=centers)
            raise_if()

    def gram_block(self, kernel, x, z):
        """K(X, Z) through the hooks."""
        self._pre(x.shape[0], z.shape[0])
        out = self.inner.gram_block(kernel, x, z)
        return corrupt("gram.nan_tile", out) if active() else out

    def masked_quadform(self, kernel, x_cand, z, mask, reg):
        """Eq. 3 quadratic form through the hooks."""
        self._pre(x_cand.shape[0], z.shape[0])
        return self.inner.masked_quadform(kernel, x_cand, z, mask, reg)

    def rls_scores(self, kernel, x_cand, z, z_mask, reg, lamn):
        """Eq. 3 scores through the hooks."""
        self._pre(x_cand.shape[0], z.shape[0])
        return self.inner.rls_scores(kernel, x_cand, z, z_mask, reg, lamn)

    def knm_quadratic(self, kernel, x, z):
        """CG quadratic op whose every call passes through the hooks."""
        inner_op = self.inner.knm_quadratic(kernel, x, z)

        def op(v):
            self._pre(x.shape[0], z.shape[0])
            return inner_op(v)

        return op

    def knm_t(self, kernel, x, z, y):
        """K_nM^T y through the hooks."""
        self._pre(x.shape[0], z.shape[0])
        return self.inner.knm_t(kernel, x, z, y)

    def knm_operators(self, kernel, x, z, y):
        """(quadratic op, K_nM^T y) with both legs hooked."""
        return self.knm_quadratic(kernel, x, z), self.knm_t(kernel, x, z, y)

    def knm_matvec(self, kernel, x, z, v):
        """K(X, Z) v through the hooks (the serving dispatch)."""
        self._pre(x.shape[0], z.shape[0])
        out = self.inner.knm_matvec(kernel, x, z, v)
        return corrupt("gram.nan_tile", out) if active() else out

    def __getattr__(self, item):
        return getattr(self.inner, item)


@dataclasses.dataclass
class VirtualClock:
    """A deterministic manual clock: call it for "now", ``advance`` to move.

    Drop-in for ``AsyncKrrServer``'s ``clock=`` so overload traces run in
    virtual time — pair ``advance`` with the ``dispatch.latency`` fault's
    ``advance=`` hook and simulated dispatches cost simulated seconds.
    """

    t: float = 0.0

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> None:
        """Move the clock forward by ``dt`` seconds (must be >= 0)."""
        if dt < 0:
            raise ValueError(f"cannot advance a clock backwards ({dt})")
        self.t += dt


Hook = Callable[..., None]
