"""Production training launcher.

    python -m repro.launch.train --arch qwen3-32b --steps 200 \
        --ckpt-dir /ckpt/run1 [--smoke] [--mesh local|single|multi]

On real hardware --mesh single/multi builds the production mesh; on this
CPU container --smoke --mesh local runs the identical code path (pjit,
sharded state, fault-tolerant supervised loop, async checkpoints) on a
1-device mesh. The loop is deterministic-resumable: state restores from the
latest checkpoint and the data pipeline replays by step index.
"""
from __future__ import annotations

import argparse
import logging
import time

import jax

from repro.checkpoint import AsyncCheckpointer, latest_step, restore_checkpoint
from repro.configs import get_config, list_archs, smoke
from repro.data import SyntheticLM
from repro.launch.mesh import make_local_mesh, make_production_mesh
from repro.optim import OptConfig
from repro.runtime import FaultTolerantLoop, HeartbeatMonitor
from repro.sharding.rules import MeshCtx, set_mesh_ctx
from repro.training import make_train_step, train_state_init

log = logging.getLogger("repro.train")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list_archs(), required=True)
    ap.add_argument("--smoke", action="store_true", help="reduced config")
    ap.add_argument("--mesh", choices=["local", "single", "multi"], default="local")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--schedule", choices=["cosine", "wsd"], default=None)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--loss-chunks", type=int, default=4)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    logging.basicConfig(level=logging.INFO, format="%(asctime)s %(message)s")
    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke(cfg)
    # minicpm ships with WSD (arXiv:2404.06395); others default cosine
    schedule = args.schedule or ("wsd" if args.arch.startswith("minicpm") else "cosine")
    opt_cfg = OptConfig(peak_lr=args.lr, warmup=max(5, args.steps // 20),
                        total_steps=args.steps, schedule=schedule)

    mesh = {"local": lambda: make_local_mesh(("data", "model")),
            "single": lambda: make_production_mesh(multi_pod=False),
            "multi": lambda: make_production_mesh(multi_pod=True)}[args.mesh]()
    set_mesh_ctx(MeshCtx(mesh=mesh))

    pipe = SyntheticLM(cfg.vocab_size, batch=args.batch, seq=args.seq, seed=0)
    step_jit = jax.jit(make_train_step(cfg, opt_cfg, loss_chunks=args.loss_chunks),
                       donate_argnums=(0,))

    state = train_state_init(cfg, jax.random.PRNGKey(0))
    start = 0
    ckpt = AsyncCheckpointer(args.ckpt_dir) if args.ckpt_dir else None
    if ckpt and latest_step(args.ckpt_dir) is not None:
        start, state = restore_checkpoint(args.ckpt_dir, state)
        log.info("restored checkpoint at step %d", start)

    monitor = HeartbeatMonitor()
    metrics_holder = {}

    def step_fn(st, i):
        st, m = step_jit(st, pipe.batch_at(i))
        if (i + 1) % args.log_every == 0:
            log.info("step %d loss %.4f lr %.2e gnorm %.3f", i + 1,
                     float(m["loss"]), float(m["lr"]), float(m["grad_norm"]))
        metrics_holder["last"] = m
        return st, m

    t0 = time.time()
    if ckpt:
        def restore():
            s = latest_step(args.ckpt_dir)
            _, st = restore_checkpoint(args.ckpt_dir, state)
            return s, st

        loop = FaultTolerantLoop(step_fn, ckpt, ckpt_every=args.ckpt_every,
                                 monitor=monitor)
        state, end = loop.run(state, start, args.steps - start, restore)
    else:
        for i in range(start, args.steps):
            t1 = time.perf_counter()
            state, _ = step_fn(state, i)
            monitor.record(i, time.perf_counter() - t1)
    dt = time.time() - t0
    tokens = (args.steps - start) * args.batch * args.seq
    log.info("done: %.1fs, %.0f tok/s, median step %.3fs, %d stragglers",
             dt, tokens / max(dt, 1e-9), monitor.median, len(monitor.stragglers))


if __name__ == "__main__":
    main()
