"""ShapeDtypeStruct stand-ins for every (arch x shape) dry-run cell.

``input_specs(cfg, shape_name, ctx)`` returns (fn, args) where fn is the
step to lower and args are ShapeDtypeStructs carrying NamedShardings — no
device allocation ever happens for full-size configs.

Shape set (assigned):
  train_4k     seq 4096,  global_batch 256  -> train_step
  prefill_32k  seq 32768, global_batch 32   -> prefill_logits (serve)
  decode_32k   seq 32768 KV, batch 128      -> decode_step    (serve)
  long_500k    seq 524288 KV, batch 1       -> decode_step    (serve, SP)

Skips (documented in DESIGN.md §6): long_500k for pure full-attention
archs; decode shapes for encoder-only archs.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from ..models import (cache_specs, decode_step, init_cache, init_params,
                      padded_vocab, param_specs)
from ..models.config import ArchConfig
from ..optim import OptConfig, adamw_init, opt_state_specs
from ..serving.engine import prefill_logits
from ..sharding.rules import MeshCtx, logical_to_spec
from ..training import TrainState, make_train_step

SHAPES = {
    "train_4k": dict(seq=4096, batch=256, kind="train"),
    "prefill_32k": dict(seq=32768, batch=32, kind="prefill"),
    "decode_32k": dict(seq=32768, batch=128, kind="decode"),
    "long_500k": dict(seq=524288, batch=1, kind="decode"),
}

FULL_ATTENTION_FAMILIES = ("dense", "moe", "vlm")  # no sub-quadratic path


def cell_supported(cfg: ArchConfig, shape_name: str) -> tuple[bool, str]:
    info = SHAPES[shape_name]
    if info["kind"] == "decode" and not cfg.has_decode:
        return False, "encoder-only arch: no decode step"
    if shape_name == "long_500k" and cfg.family in FULL_ATTENTION_FAMILIES \
            and cfg.attention_impl != "bless_nystrom":
        return False, "full-attention arch: 500k KV needs sub-quadratic attention"
    if info["kind"] == "train" and shape_name == "train_4k" and not cfg.causal:
        pass  # encoder training is fine
    return True, ""


def _sds(shape, dtype, sharding):
    return jax.ShapeDtypeStruct(shape, dtype, sharding=sharding)


def _ns(ctx: MeshCtx, *logical):
    from jax.sharding import NamedSharding

    return NamedSharding(ctx.mesh, logical_to_spec(*logical, ctx=ctx))


def _with_sharding(tree_shapes: Any, tree_specs: Any, mesh) -> Any:
    from jax.sharding import NamedSharding

    return jax.tree.map(
        lambda s, p: _sds(s.shape, s.dtype, NamedSharding(mesh, p)),
        tree_shapes, tree_specs)


def batch_specs(cfg: ArchConfig, b: int, s: int, ctx: MeshCtx) -> dict:
    """Input batch ShapeDtypeStructs for a full forward/train step."""
    bat: dict[str, Any] = {}
    tok_sh = _ns(ctx, "batch", None)
    if cfg.embed_inputs:
        bat["tokens"] = _sds((b, s), jnp.int32, tok_sh)
    else:
        bat["frames"] = _sds((b, s, cfg.d_model), jnp.bfloat16, _ns(ctx, "batch", None, None))
    bat["labels"] = _sds((b, s), jnp.int32, tok_sh)
    if cfg.pos == "mrope":
        bat["mrope_positions"] = _sds((b, 3, s), jnp.int32, _ns(ctx, "batch", None, None))
    if cfg.extra_image_tokens:
        bat["pixel_embeds"] = _sds((b, cfg.extra_image_tokens, cfg.d_model), jnp.bfloat16,
                                   _ns(ctx, "batch", None, None))
    return bat


def params_sds(cfg: ArchConfig, ctx: MeshCtx) -> Any:
    shapes = jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))
    return _with_sharding(shapes, param_specs(cfg, ctx), ctx.mesh)


def input_specs(cfg: ArchConfig, shape_name: str, ctx: MeshCtx,
                opt_cfg: Optional[OptConfig] = None,
                loss_chunks: int = 32,
                kv_len: Optional[int] = None,
                microbatches: int = 1,
                zero: int = 3) -> tuple[Callable, tuple, tuple[int, ...]]:
    """(step_fn, arg ShapeDtypeStructs, donate_argnums) for one cell.

    Donation: the train state and the decode cache are consumed in place —
    on real hardware this is what keeps optimizer+cache memory flat.
    kv_len: decode-cache length override — the BLESS leverage-score KV
    compression serving mode (models.attention.bless_compress_cache keeps
    the top-M RLS keys; the decode step then runs against an M-entry cache).
    """
    info = SHAPES[shape_name]
    b, s = info["batch"], info["seq"]
    if kv_len is not None and info["kind"] == "decode":
        s = kv_len
    kind = info["kind"]
    if kind == "train":
        opt_cfg = opt_cfg or OptConfig()
        # ZeRO-3 (default): params fsdp+tp sharded, re-gathered every
        # microbatch. ZeRO-1: params tp-only (replicated over data; no
        # per-microbatch gathers), optimizer state fsdp+tp sharded — the
        # right trade once grad accumulation is on (EXPERIMENTS.md §Perf).
        p_ctx = dataclasses.replace(ctx, fsdp=False) if zero == 1 else ctx
        pspecs = param_specs(cfg, p_ctx)
        shapes = jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))
        p_sds = _with_sharding(shapes, pspecs, p_ctx.mesh)
        opt_shapes = jax.eval_shape(adamw_init, shapes)
        o_sds = _with_sharding(opt_shapes, opt_state_specs(param_specs(cfg, ctx)),
                               ctx.mesh)
        state = TrainState(params=p_sds, opt=o_sds)
        bat = batch_specs(cfg, b, s, ctx)
        from jax.sharding import NamedSharding

        gshard = jax.tree.map(lambda p: NamedSharding(ctx.mesh, p),
                              param_specs(cfg, ctx)) if microbatches > 1 else None
        fn = make_train_step(cfg, opt_cfg, loss_chunks=loss_chunks,
                             microbatches=microbatches, grad_shardings=gshard)
        return fn, (state, bat), (0,)

    serve_ctx = dataclasses.replace(ctx, fsdp=False)
    p_sds = params_sds(cfg, serve_ctx)
    if kind == "prefill":
        bat = batch_specs(cfg, b, s, serve_ctx)
        bat.pop("labels")
        return (lambda params, batch: prefill_logits(params, cfg, batch)), (p_sds, bat), ()

    # decode: batch over (pod,data); KV seq over model (decode_32k) or over
    # data+model (long_500k, batch=1 — SP across every chip)
    seq_logical = "seq_shard_wide" if b == 1 else "seq_model"
    rules = dict(serve_ctx.rules)
    rules["seq_model"] = ("model",)
    if b == 1:
        rules["batch"] = ()  # batch=1: nothing to shard
    dctx = dataclasses.replace(serve_ctx, rules=rules)
    p_sds = params_sds(cfg, dctx)
    cshapes = jax.eval_shape(lambda: init_cache(cfg, b, s))
    c_sds = _with_sharding(cshapes, cache_specs(cfg, dctx, seq_logical=seq_logical), dctx.mesh)
    tok = _sds((b,), jnp.int32, _ns(dctx, "batch"))
    pos = _sds((), jnp.int32, _ns(dctx))
    if cfg.pos == "mrope":
        mp = _sds((b, 3, 1), jnp.int32, _ns(dctx, "batch", None, None))

        def fn(params, cache, token, pos, mrope_pos):
            return decode_step(params, cfg, cache, token, pos, mrope_pos=mrope_pos)

        return fn, (p_sds, c_sds, tok, pos, mp), (1,)

    def fn(params, cache, token, pos):
        return decode_step(params, cfg, cache, token, pos)

    return fn, (p_sds, c_sds, tok, pos), (1,)
