"""Roofline terms from a compiled dry-run artifact.

Collective bytes come from the post-SPMD HLO text (per-device shapes):
operand/result bytes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute, with **while-loop trip-count multipliers**
— layer stacks are lax.scan'd, so a collective inside the loop body executes
`known_trip_count` times (XLA's aggregate cost_analysis counts it once,
which is why FLOPs/HBM-bytes use the analytic model in cost_model.py
instead; see tests/test_roofline.py for the cross-check).

Hardware constants: TPU v5e — 197 TFLOP/s bf16/chip, 819 GB/s HBM,
~50 GB/s/link ICI (per the assignment).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # B/s
ICI_BW = 45e9  # B/s usable per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"(\w+?)\[([0-9,]*)\]")
_COMP_RE = re.compile(r"^(?:ENTRY )?%?([\w\.\-]+) (?:\([^)]*\) -> .*?)?\{", re.M)
_WHILE_RE = re.compile(
    r"while\(.*?\).*?body=%?([\w\.\-]+).*?(?:known_trip_count.....n...(\d+))?", )
_CALL_RE = re.compile(
    r"(?:call|fusion)\(.*?\).*?(?:to_apply|calls)=%?([\w\.\-]+)")
_COND_RE = re.compile(
    r"conditional\(.*?branch_computations=\{([^}]*)\}|"
    r"conditional\(.*?true_computation=%?([\w\.\-]+), false_computation=%?([\w\.\-]+)")
_COLL_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:[\w\[\]0-9,{}]+))\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start|-done)?\(")


def _shape_bytes(text: str, f32_weight: float = 1.0) -> int:
    total = 0.0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        w = f32_weight if dt == "f32" else 1.0
        total += n * _DTYPE_BYTES[dt] * w
    return int(total)


def _split_computations(hlo: str) -> dict[str, str]:
    """computation name -> body text (brace-balanced, top-level defs)."""
    comps: dict[str, str] = {}
    i = 0
    lines = hlo.splitlines()
    cur_name, buf, depth = None, [], 0
    for line in lines:
        if cur_name is None:
            m = re.match(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*(?:\(|=\s*\()?.*\{\s*$", line)
            if m and ("{" in line) and ("=" not in line.split("{")[0].split("(")[0]):
                cur_name = m.group(1)
                buf = [line]
                depth = line.count("{") - line.count("}")
                if depth <= 0:
                    comps[cur_name] = "\n".join(buf)
                    cur_name = None
                continue
        else:
            buf.append(line)
            depth += line.count("{") - line.count("}")
            if depth <= 0:
                comps[cur_name] = "\n".join(buf)
                cur_name = None
    return comps


def _local_collectives(body: str) -> dict[str, int]:
    out = {k: 0 for k in COLLECTIVES}
    for m in _COLL_RE.finditer(body):
        shape_txt, kind, phase = m.group(1), m.group(2), m.group(3)
        if phase == "-done":
            continue  # counted at -start (or the sync form)
        out[kind] += _shape_bytes(shape_txt, f32_weight=_F32_WEIGHT)
    return out


def _edges(body: str) -> list[tuple[str, int]]:
    """(callee, multiplier) edges of one computation body."""
    edges: list[tuple[str, int]] = []
    for line in body.splitlines():
        if " while(" in line:
            mb = re.search(r"body=%?([\w\.\-]+)", line)
            mt = re.search(r"known_trip_count[^0-9]*(\d+)", line)
            if mb:
                edges.append((mb.group(1), int(mt.group(1)) if mt else 1))
        for m in re.finditer(r"(?:to_apply|calls)=%?([\w\.\-]+)", line):
            edges.append((m.group(1), 1))
        m = re.search(r"branch_computations=\{([^}]*)\}", line)
        if m:
            for c in m.group(1).split(","):
                edges.append((c.strip().lstrip("%"), 1))
    return edges


def collective_bytes(hlo_text: str, *, cpu_bf16_correction: bool = True) -> dict[str, int]:
    """Per-device collective bytes with while trip-count multipliers.

    cpu_bf16_correction: XLA:CPU's float-normalization pass upcasts every
    bf16 op — including collectives — to f32 (verified: a bf16 psum compiles
    to `f32[..] all-reduce(convert(..))` on this backend; TPU keeps bf16 on
    the wire). With the flag, f32 collective bytes are counted at half,
    reflecting the TPU target. Genuinely-f32 collectives (norm-param grads,
    loss scalars) are orders of magnitude smaller, so the approximation
    errs by <1%.
    """
    if cpu_bf16_correction:
        global _F32_WEIGHT
        _F32_WEIGHT = 0.5
    try:
        return _collective_bytes_impl(hlo_text)
    finally:
        _F32_WEIGHT = 1.0


_F32_WEIGHT = 1.0


def _collective_bytes_impl(hlo_text: str) -> dict[str, int]:
    comps = _split_computations(hlo_text)
    entry = None
    for line in hlo_text.splitlines():
        if line.startswith("ENTRY"):
            m = re.match(r"ENTRY\s+%?([\w\.\-]+)", line)
            if m:
                entry = m.group(1)
            break
    local = {name: _local_collectives(body) for name, body in comps.items()}
    edges = {name: _edges(body) for name, body in comps.items()}

    total = {k: 0 for k in COLLECTIVES}
    seen: set[tuple[str, int]] = set()

    def visit(name: str, mult: int, depth: int = 0) -> None:
        if name not in comps or depth > 64:
            return
        loc = local.get(name, {})
        for k in COLLECTIVES:
            total[k] += loc.get(k, 0) * mult
        for callee, m in edges.get(name, []):
            if callee != name:
                visit(callee, mult * m, depth + 1)

    if entry is None and comps:
        entry = next(iter(comps))
    if entry:
        visit(entry, 1)
    else:  # fallback: flat count
        total = _local_collectives(hlo_text)
    return total


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_device: float  # analytic (cost_model)
    bytes_per_device: float  # analytic HBM traffic
    coll_bytes_per_device: float  # parsed from compiled HLO
    coll_breakdown: dict[str, int]
    peak_memory_per_device: float
    model_flops: float  # 6*N*D (dense) / 6*N_active*D (MoE); 2*N*D serve

    @property
    def t_compute(self) -> float:
        return self.flops_per_device / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.bytes_per_device / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes_per_device / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        total = self.flops_per_device * self.chips
        return self.model_flops / total if total else 0.0

    @property
    def roofline_fraction(self) -> float:
        t_useful = (self.model_flops / self.chips) / PEAK_FLOPS
        t_bound = max(self.t_compute, self.t_memory, self.t_collective)
        return t_useful / t_bound if t_bound else 0.0

    def row(self) -> dict[str, Any]:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "t_compute_s": self.t_compute, "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective, "bottleneck": self.bottleneck,
            "model_flops": self.model_flops,
            "hlo_flops_per_dev": self.flops_per_device,
            "useful_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
            "peak_mem_gb": self.peak_memory_per_device / 2**30,
            "coll_gb": {k: round(v / 2**30, 4) for k, v in self.coll_breakdown.items() if v},
        }
