# Multi-pod dry-run: these two lines MUST run before any other import —
# jax locks the device count on first init (see assignment §MULTI-POD).
import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

import argparse  # noqa: E402
import dataclasses  # noqa: E402
import gzip  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402

from repro.configs import get_config, list_archs  # noqa: E402
from repro.launch import cost_model, hlo_analysis  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.specs import SHAPES, cell_supported, input_specs  # noqa: E402
from repro.sharding.rules import MeshCtx, set_mesh_ctx  # noqa: E402

"""For every (arch x input-shape x mesh) cell: lower + compile the step
function on placeholder devices, print memory_analysis / cost_analysis, and
derive the roofline terms (hlo_analysis). Results are cached as JSON under
exp/dryrun/ for EXPERIMENTS.md §Dry-run/§Roofline.

Usage:
  python -m repro.launch.dryrun --arch qwen3-32b --shape train_4k --mesh both
  python -m repro.launch.dryrun --all            # every supported cell
"""


def model_flops(cfg, shape_name: str) -> float:
    info = SHAPES[shape_name]
    n_active = cfg.active_param_count()
    if info["kind"] == "train":
        tokens = info["batch"] * info["seq"]
        return 6.0 * n_active * tokens
    if info["kind"] == "prefill":
        return 2.0 * n_active * info["batch"] * info["seq"]
    return 2.0 * n_active * info["batch"]  # decode: one token per sequence


def run_cell(arch: str, shape_name: str, multi_pod: bool, *,
             attention_impl: str | None = None,
             moe_sharding: str | None = None,
             hlo_path: str | None = None,
             kv_len: int | None = None,
             microbatches: int = 1,
             zero: int = 3) -> dict:
    cfg = get_config(arch)
    if attention_impl:
        cfg = dataclasses.replace(cfg, attention_impl=attention_impl)
    if moe_sharding:
        cfg = dataclasses.replace(cfg, moe_sharding=moe_sharding)
    ok, why = cell_supported(cfg, shape_name)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    if not ok:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                "status": "skipped", "reason": why}
    mesh = make_production_mesh(multi_pod=multi_pod)
    ctx = MeshCtx(mesh=mesh)
    set_mesh_ctx(ctx)
    try:
        fn, args, donate = input_specs(cfg, shape_name, ctx, kv_len=kv_len,
                                       microbatches=microbatches, zero=zero)
        t0 = time.time()
        lowered = jax.jit(fn, donate_argnums=donate).lower(*args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0
        mem = compiled.memory_analysis()
        print(f"[{arch} x {shape_name} x {mesh_name}] memory_analysis: "
              f"args={mem.argument_size_in_bytes/2**30:.2f}GiB "
              f"temps={mem.temp_size_in_bytes/2**30:.2f}GiB "
              f"out={mem.output_size_in_bytes/2**30:.2f}GiB")
        cost = cost_model.xla_cost_analysis(compiled)
        print(f"  cost_analysis: flops={cost.get('flops', 0):.3e} "
              f"bytes={cost.get('bytes accessed', 0):.3e} "
              "(loop bodies counted once by XLA - see cost_model)")
        info = SHAPES[shape_name]
        chips = mesh.devices.size
        s_kv = (kv_len or info["seq"]) if info["kind"] == "decode" else None
        ana = cost_model.step_costs(
            cfg, info["kind"], info["batch"], 1 if info["kind"] == "decode" else info["seq"],
            chips, s_kv=s_kv)
        hlo_text = compiled.as_text()
        if hlo_path:
            with gzip.open(hlo_path, "wt") as f:
                f.write(hlo_text)
        coll = hlo_analysis.collective_bytes(hlo_text)
        roof = hlo_analysis.Roofline(
            arch=arch, shape=shape_name, mesh=mesh_name, chips=chips,
            flops_per_device=ana["flops_per_device"],
            bytes_per_device=ana["hbm_bytes_per_device"],
            coll_bytes_per_device=float(sum(coll.values())),
            coll_breakdown=coll,
            peak_memory_per_device=float(mem.argument_size_in_bytes
                                         + mem.temp_size_in_bytes),
            model_flops=model_flops(cfg, shape_name))
        row = roof.row()
        row.update(status="ok", lower_s=round(t_lower, 1),
                   compile_s=round(t_compile, 1),
                   attention_impl=cfg.attention_impl,
                   xla_flops_flat=cost.get("flops", 0),
                   mem_args_gb=round(mem.argument_size_in_bytes / 2**30, 3),
                   mem_temps_gb=round(mem.temp_size_in_bytes / 2**30, 3),
                   flops_breakdown={k: v for k, v in ana["flops_breakdown"].items() if v})
        return row
    except Exception as e:  # noqa: BLE001 — report failures as data
        traceback.print_exc()
        return {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                "status": "failed", "error": f"{type(e).__name__}: {e}"}
    finally:
        set_mesh_ctx(None)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list_archs())
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="both")
    ap.add_argument("--attention-impl", choices=["full", "bless_nystrom"])
    ap.add_argument("--moe-sharding", choices=["auto", "ep", "tp", "replicate"])
    ap.add_argument("--kv-cache-len", type=int, default=None,
                    help="decode-cache override: BLESS-compressed KV serving")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--zero", type=int, choices=[1, 3], default=3)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="exp/dryrun")
    args = ap.parse_args()

    cells = []
    if args.all:
        for a in list_archs():
            for s in SHAPES:
                cells.append((a, s))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells.append((args.arch, args.shape))
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    os.makedirs(args.out, exist_ok=True)
    for arch, shape in cells:
        for mp in meshes:
            tag = f"{arch}__{shape}__{'2x16x16' if mp else '16x16'}"
            if args.attention_impl:
                tag += f"__{args.attention_impl}"
            path = os.path.join(args.out, tag + ".json")
            if os.path.exists(path):
                print(f"cached: {tag}")
                continue
            row = run_cell(arch, shape, mp, attention_impl=args.attention_impl,
                           moe_sharding=args.moe_sharding,
                           hlo_path=path.replace(".json", ".hlo.gz"),
                           kv_len=args.kv_cache_len,
                           microbatches=args.microbatches, zero=args.zero)
            with open(path, "w") as f:
                json.dump(row, f, indent=1)
            print(f"{tag}: {row['status']} "
                  + (f"bottleneck={row.get('bottleneck')} "
                     f"roofline={row.get('roofline_fraction', 0):.3f}"
                     if row["status"] == "ok" else row.get("reason", row.get("error", ""))))


if __name__ == "__main__":
    main()
