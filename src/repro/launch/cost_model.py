"""Analytic FLOP / HBM-byte model for the roofline.

Why analytic: XLA's cost_analysis counts each while-loop body ONCE — with
layers under lax.scan and chunked attention/loss under lax.map, the
reported FLOPs undercount by orders of magnitude on this backend. We control
every matmul in the model, so exact per-component accounting is feasible
and auditable; tests/test_roofline.py cross-checks it against an *unrolled*
small-config compile where XLA's counter is correct. Collective bytes, in
contrast, ARE taken from the compiled HLO (hlo_analysis.py) with
trip-count multipliers parsed from `known_trip_count`.

Conventions: matmul (m,k)x(k,n) = 2mkn FLOPs. Train = fwd + 2x bwd + 1x
remat re-fwd (nothing_saveable policy) = 4x fwd matmul FLOPs. Padded
q-heads and MoE capacity slots are counted as spent FLOPs (they are), which
is exactly what the MODEL_FLOPS/HLO ratio is meant to expose.
"""
from __future__ import annotations

import dataclasses

from ..models.config import ArchConfig
from ..models.model import padded_vocab

TP = 16


def xla_cost_analysis(compiled) -> dict:
    """Normalize ``Compiled.cost_analysis()`` across jax versions.

    Older jax returns a per-device list of dicts, newer jax a single dict
    (and either may return None for backends without a cost model). Returns
    the entry-computation dict, or {} when unavailable.
    """
    cost = compiled.cost_analysis()
    if cost is None:
        return {}
    if isinstance(cost, (list, tuple)):
        return dict(cost[0]) if cost else {}
    return dict(cost)


@dataclasses.dataclass
class CostBreakdown:
    flops_fwd: float  # one forward pass, whole job
    hbm_bytes: float  # per-device traffic per step
    breakdown: dict


def _attn_layer_flops(cfg: ArchConfig, tokens: int, s_kv: int) -> float:
    hp = cfg.padded_heads(TP)
    kvp = hp if cfg.n_kv_heads == cfg.n_heads else cfg.n_kv_heads
    hd, d = cfg.head_dim, cfg.d_model
    proj = 2 * tokens * d * (hp * hd) * 2  # wq + wo
    proj += 2 * tokens * d * (kvp * hd) * 2  # wk + wv
    if cfg.attention_impl == "bless_nystrom" and s_kv > cfg.nystrom_landmarks:
        m = cfg.nystrom_landmarks
        core = 2 * tokens * m * (hp * hd) * 2  # F1, F2-style products
        core += 2 * tokens * m * m  # pinv application (amortized)
        core += 2 * tokens * m * hd * hp  # (F2 V) and landmark matmuls
    else:
        causal_frac = 0.5 if cfg.causal and tokens == s_kv else 1.0
        core = 2 * 2 * tokens * s_kv * (hp * hd) * causal_frac  # QK^T + PV
    return proj + core


def _mamba_layer_flops(cfg: ArchConfig, tokens: int, chunk: int = 256) -> float:
    d, di, ns = cfg.d_model, cfg.d_inner, cfg.ssm_state
    proj = 2 * tokens * d * (2 * di + 2 * ns + cfg.ssm_heads)  # in_proj
    proj += 2 * tokens * di * d  # out_proj
    conv = 2 * tokens * (di + 2 * ns) * cfg.ssm_conv
    q = min(chunk, tokens)
    # chunked SSD einsums (B*nc*Q = tokens):
    #   CB^T: Q*ns/token; y_diag: Q*di/token; states+y_off: 2*di*ns/token
    ssd = 2 * tokens * (q * ns + q * di + 2 * di * ns)
    return proj + conv + ssd


def _mlp_flops(cfg: ArchConfig, tokens: int) -> float:
    mult = 3 if cfg.mlp_act in ("swiglu", "geglu") else 2
    return 2 * tokens * cfg.d_model * cfg.d_ff * mult


def _moe_layer_flops(cfg: ArchConfig, tokens: int, seq: int) -> float:
    mult = 3 if cfg.mlp_act in ("swiglu", "geglu") else 2
    router = 2 * tokens * cfg.d_model * cfg.n_experts
    capacity = max(8, int(seq * cfg.top_k * cfg.capacity_factor / cfg.n_experts))
    groups = tokens // seq
    expert_tokens = groups * cfg.n_experts * capacity  # capacity slots are spent
    expert = 2 * expert_tokens * cfg.d_model * cfg.d_ff * mult
    shared = (2 * tokens * cfg.d_model * cfg.shared_expert_ff * mult
              if cfg.shared_expert_ff else 0)
    return router + expert + shared


def forward_flops(cfg: ArchConfig, batch: int, seq: int, *, s_kv: int | None = None,
                  decode: bool = False) -> CostBreakdown:
    """One forward pass over batch x seq tokens (decode: seq=1, s_kv=cache)."""
    tokens = batch * seq
    s_kv = s_kv or seq
    vp = padded_vocab(cfg)
    br = {"embed_logits": 2 * tokens * cfg.d_model * vp if cfg.embed_inputs or True else 0}
    attn = mamba = mlp = moe = 0.0
    for i in range(cfg.n_layers):
        if cfg.mixer_kind(i) == "attn":
            attn += _attn_layer_flops(cfg, tokens, s_kv)
        else:
            if decode:
                # recurrent step: state update + conv + projections
                d, di, ns, nh, hp = (cfg.d_model, cfg.d_inner, cfg.ssm_state,
                                     cfg.ssm_heads, cfg.ssm_headdim)
                mamba += 2 * tokens * (d * (2 * di + 2 * ns + nh) + di * d)
                mamba += 2 * tokens * nh * hp * ns * 2
            else:
                mamba += _mamba_layer_flops(cfg, tokens)
        kind = cfg.mlp_kind(i)
        if kind == "dense":
            mlp += _mlp_flops(cfg, tokens)
        elif kind == "moe":
            moe += _moe_layer_flops(cfg, tokens, seq)
    br.update(attn=attn, mamba=mamba, mlp=mlp, moe=moe)
    total = sum(br.values())
    return CostBreakdown(flops_fwd=total, hbm_bytes=0.0, breakdown=br)


def param_bytes(cfg: ArchConfig, dtype_bytes: int = 2) -> float:
    return cfg.param_count() * dtype_bytes


def step_costs(cfg: ArchConfig, shape_kind: str, batch: int, seq: int, chips: int,
               *, s_kv: int | None = None) -> dict:
    """Per-device FLOPs and HBM bytes for one step of the given kind."""
    decode = shape_kind == "decode"
    fb = forward_flops(cfg, batch, 1 if decode else seq,
                       s_kv=s_kv or seq, decode=decode)
    if shape_kind == "train":
        total_flops = 4.0 * fb.flops_fwd  # fwd + re-fwd(remat) + 2x bwd
    else:
        total_flops = fb.flops_fwd
    flops_dev = total_flops / chips

    p_bytes = param_bytes(cfg)  # bf16 weights
    if shape_kind == "train":
        # params read twice (fwd+refwd) + grads written + adam: master/mu/nu
        # read+write in fp32 (3 * 4B * 2) + bf16 param write
        w_traffic = p_bytes * 2 + p_bytes + cfg.param_count() * (3 * 4 * 2 + 2)
        act = 2 * batch * seq * cfg.d_model * cfg.n_layers * 2  # ckpt in+out
        traffic = w_traffic + act * 2
    elif shape_kind == "prefill":
        act = 2 * batch * seq * cfg.d_model * cfg.n_layers * 2
        traffic = p_bytes + act
    else:  # decode: weights + full KV/state read per token
        kv = 0
        for i in range(cfg.n_layers):
            if cfg.mixer_kind(i) == "attn":
                kvp = (cfg.padded_heads(TP) if cfg.n_kv_heads == cfg.n_heads
                       else cfg.n_kv_heads)
                kv += 2 * batch * (s_kv or seq) * kvp * cfg.head_dim * 2
            else:
                kv += batch * cfg.ssm_heads * cfg.ssm_headdim * cfg.ssm_state * 4
        traffic = p_bytes + kv
    return {"flops_per_device": flops_dev, "hbm_bytes_per_device": traffic / chips,
            "flops_breakdown": fb.breakdown, "flops_total": total_flops}
