"""Production meshes.

Single pod: (data=16, model=16) = 256 chips (one v5e pod).
Multi pod:  (pod=2, data=16, model=16) = 512 chips; the `pod` axis is pure
data parallelism over the cross-pod interconnect (gradient all-reduce only,
where runtime.compress applies).

Functions, not module constants — importing this module never touches jax
device state (the dry-run sets XLA_FLAGS *before* any jax init).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_pipeline_mesh():
    """Optional PP mesh: 512 = pipe(4) x data(8) x model(16)."""
    return jax.make_mesh((4, 8, 16), ("pipe", "data", "model"))


def make_local_mesh(axes: tuple[str, ...] = ("data",)):
    """All local devices on one axis (CPU tests / the core library)."""
    n = len(jax.devices())
    return jax.make_mesh((n,) + (1,) * (len(axes) - 1), axes)
