"""Pallas TPU kernels for the paper's compute hot-spots + the LM fast path.

Each subpackage: <name>.py (pl.pallas_call + BlockSpec VMEM tiling),
ops.py (jit'd public wrapper, pad/dispatch/interpret switch), ref.py
(pure-jnp oracle). Validated in interpret mode on CPU; compiled natively
on TPU (common.default_interpret()).

  gram           k(X, Z) blocked Gram — every BLESS level's bulk work
  quadform       rowsum((G W) * G) — Eq. 3 leverage-score epilogue, fused
  falkon_matvec  K_nM^T (K_nM v) — FALKON CG inner loop, Gram never hits HBM
  flash_attention causal GQA streaming-softmax attention (LM prefill/train)
  ssd            Mamba-2 SSD chunk scan, state carried in VMEM (SSM archs)
"""
from . import falkon_matvec, flash_attention, gram, quadform, ssd  # noqa: F401
