"""Pallas TPU kernel: blocked Gram matrix k(X, Z).

The paper's universal hot-spot — every BLESS level and every FALKON CG
iteration starts from Gram blocks. TPU mapping (DESIGN.md §2):
``||x-z||^2 = ||x||^2 + ||z||^2 - 2 X Z^T`` puts all the FLOPs in one MXU
matmul per tile; the exp/epilogue runs on the VPU while the next tile's
matmul occupies the MXU.

Tiling: grid (n/bn, m/bm); X tile (bn, d) and Z tile (bm, d) live in VMEM,
``d`` is padded to a multiple of 128 (lane width) by ops.py. bn=bm=256 keeps
the working set (2*256*d + 256*256) * 4B well under VMEM for d <= 2048.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ...families import get_family


def _gram_kernel(x_ref, z_ref, o_ref, *, kind: str, inv_scale: float, bf16: bool):
    fam = get_family(kind)  # kind is static: resolved once per trace
    x = x_ref[...].astype(jnp.float32)  # (bn, d)
    z = z_ref[...].astype(jnp.float32)  # (bm, d)
    # bf16: MXU operands dropped to bf16, fp32 accumulation; norms/epilogue
    # stay fp32 (only the distance cross-term loses precision — DESIGN.md §2).
    xc, zc = (x.astype(jnp.bfloat16), z.astype(jnp.bfloat16)) if bf16 else (x, z)
    prod = jax.lax.dot_general(xc, zc, (((1,), (1,)), ((), ())),
                               preferred_element_type=jnp.float32)  # (bn, bm) on MXU
    if fam.dot_only:
        o_ref[...] = fam.epilogue(prod, inv_scale).astype(o_ref.dtype)
        return
    xn = jnp.sum(x * x, axis=-1)[:, None]
    zn = jnp.sum(z * z, axis=-1)[None, :]
    d2 = jnp.maximum(xn + zn - 2.0 * prod, 0.0)
    # the family's elementwise epilogue runs on the VPU while the next tile's
    # matmul occupies the MXU — same function as the jnp reference formula.
    o_ref[...] = fam.epilogue(d2, inv_scale).astype(o_ref.dtype)


@partial(jax.jit, static_argnames=("kind", "bn", "bm", "interpret", "inv_scale", "bf16"))
def gram_pallas(x: jax.Array, z: jax.Array, inv_scale: float, *, kind: str = "gaussian",
                bn: int = 256, bm: int = 256, interpret: bool = True,
                bf16: bool = False) -> jax.Array:
    """k(X, Z) for pre-padded inputs: n % bn == 0, m % bm == 0, d % 128 == 0."""
    n, d = x.shape
    m = z.shape[0]
    assert n % bn == 0 and m % bm == 0 and d % 128 == 0, (n, m, d)
    return pl.pallas_call(
        partial(_gram_kernel, kind=kind, inv_scale=float(inv_scale), bf16=bf16),
        grid=(n // bn, m // bm),
        in_specs=[
            pl.BlockSpec((bn, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bm, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bn, bm), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, m), x.dtype),
        interpret=interpret,
    )(x, z)
