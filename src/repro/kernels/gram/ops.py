"""Public jit'd wrapper: pads to tile boundaries, dispatches Pallas vs ref."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ...families import get_family
from ..common import default_interpret, pad_dim, round_up
from .gram import gram_pallas
from .ref import gram_ref


def gram(x: jax.Array, z: jax.Array, sigma: float = 1.0, *, kind: str = "gaussian",
         bn: int = 256, bm: int = 256, interpret: bool | None = None,
         bf16: bool = False) -> jax.Array:
    """k(X, Z) -> (n, m). Arbitrary shapes; pads internally to (bn, bm, 128).

    ``kind`` names any registered kernel family (``repro.families``); its
    ``inv_scale`` is baked into the compiled epilogue here. ``bf16`` drops
    the MXU operands of the distance cross-term to bf16 with fp32
    accumulation (~1e-2 relative tolerance on kernel values for unit-scale
    data; see DESIGN.md §2).
    """
    inv_scale = float(get_family(kind).inv_scale(sigma))
    n, d = x.shape
    m = z.shape[0]
    interpret = default_interpret() if interpret is None else interpret
    xp = pad_dim(pad_dim(x, 0, round_up(n, bn)), 1, round_up(d, 128))
    zp = pad_dim(pad_dim(z, 0, round_up(m, bm)), 1, round_up(d, 128))
    out = gram_pallas(xp, zp, float(inv_scale), kind=kind, bn=bn, bm=bm,
                      interpret=interpret, bf16=bf16)
    return out[:n, :m]


def gram_reference(x: jax.Array, z: jax.Array, sigma: float = 1.0, *, kind: str = "gaussian") -> jax.Array:
    return gram_ref(x, z, float(get_family(kind).inv_scale(sigma)), kind=kind)
