"""Pure-jnp oracle for the gram kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def gram_ref(x: jax.Array, z: jax.Array, inv_scale: float, *, kind: str = "gaussian") -> jax.Array:
    x32 = x.astype(jnp.float32)
    z32 = z.astype(jnp.float32)
    if kind == "linear":
        return (x32 @ z32.T).astype(x.dtype)
    d2 = jnp.maximum(
        jnp.sum(x32 * x32, -1)[:, None] + jnp.sum(z32 * z32, -1)[None, :] - 2.0 * (x32 @ z32.T),
        0.0,
    )
    if kind == "gaussian":
        return jnp.exp(-d2 * inv_scale).astype(x.dtype)
    if kind == "laplacian":
        return jnp.exp(-jnp.sqrt(d2 + 1e-30) * inv_scale).astype(x.dtype)
    raise ValueError(kind)
