"""Pure-jnp oracle for the gram kernel — same family epilogues as the tiles."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ...families import get_family


def gram_ref(x: jax.Array, z: jax.Array, inv_scale: float, *, kind: str = "gaussian") -> jax.Array:
    fam = get_family(kind)
    x32 = x.astype(jnp.float32)
    z32 = z.astype(jnp.float32)
    if fam.dot_only:
        return fam.epilogue(x32 @ z32.T, inv_scale).astype(x.dtype)
    d2 = jnp.maximum(
        jnp.sum(x32 * x32, -1)[:, None] + jnp.sum(z32 * z32, -1)[None, :] - 2.0 * (x32 @ z32.T),
        0.0,
    )
    return fam.epilogue(d2, inv_scale).astype(x.dtype)
