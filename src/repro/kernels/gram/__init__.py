from . import ops
