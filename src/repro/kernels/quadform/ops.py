"""Public wrapper: pads (n, m) to tile boundaries; zero-padding is exact
because padded G columns/rows contribute 0 to the bilinear form."""
from __future__ import annotations

import jax

from ..common import default_interpret, pad_dim, round_up
from .quadform import quadform_pallas
from .ref import quadform_ref


def quadform(g: jax.Array, w: jax.Array, *, bn: int = 256, bm: int = 256,
             interpret: bool | None = None, bf16: bool = False) -> jax.Array:
    """s_i = g_i^T W g_i for each row of G. G (n, m), W (m, m) -> (n,) fp32."""
    n, m = g.shape
    interpret = default_interpret() if interpret is None else interpret
    gp = pad_dim(pad_dim(g, 0, round_up(n, bn)), 1, round_up(m, bm))
    wp = pad_dim(pad_dim(w, 0, round_up(m, bm)), 1, round_up(m, bm))
    return quadform_pallas(gp, wp, bn=bn, bm=bm, interpret=interpret, bf16=bf16)[:n]


quadform_reference = quadform_ref
