"""Pallas TPU kernel: fused leverage-score quadratic form.

Computes  s_i = sum_jk G_ij W_jk G_ik  =  rowsum((G @ W) * G)  without ever
writing G @ W to HBM — the epilogue of Eq. 3 (l~ = (K_ii - s_i)/(lam n)).
A naive two-op version moves the (n, M) product through HBM twice; fusing
keeps it in VMEM, turning the op from memory- to compute-bound for M >= 512.

Grid (i, k, j), j innermost: the (bn, bk) slab of G@W accumulates in VMEM
scratch over j, then at j == last multiplies elementwise with G[i, k-tile]
and row-reduces into the output block (indexed by i only — Pallas revisits
it across k and j, which is legal under sequential TPU grids).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _quadform_kernel(g_kj_ref, w_ref, g_ik_ref, o_ref, acc_ref, *, nj: int, nk: int,
                     bf16: bool):
    k = pl.program_id(1)
    j = pl.program_id(2)

    @pl.when((k == 0) & (j == 0))
    def _init_out():
        o_ref[...] = jnp.zeros_like(o_ref)

    @pl.when(j == 0)
    def _init_acc():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # bf16: MXU operands only; the fp32 VMEM accumulator and the elementwise
    # epilogue keep full precision (DESIGN.md §2 documents the tolerances).
    dt = jnp.bfloat16 if bf16 else jnp.float32
    g = g_kj_ref[...].astype(dt)  # (bn, bj) — G[:, j-tile]
    w = w_ref[...].astype(dt)  # (bj, bk)
    acc_ref[...] += jax.lax.dot_general(g, w, (((1,), (0,)), ((), ())),
                                        preferred_element_type=jnp.float32)

    @pl.when(j == nj - 1)
    def _epilogue():
        gk = g_ik_ref[...].astype(jnp.float32)  # (bn, bk) — G[:, k-tile]
        o_ref[...] += jnp.sum(acc_ref[...] * gk, axis=1)


@partial(jax.jit, static_argnames=("bn", "bm", "interpret", "bf16"))
def quadform_pallas(g: jax.Array, w: jax.Array, *, bn: int = 256, bm: int = 256,
                    interpret: bool = True, bf16: bool = False) -> jax.Array:
    """rowsum((G @ W) * G) for pre-padded G (n, m), W (m, m)."""
    n, m = g.shape
    assert n % bn == 0 and m % bm == 0, (n, m)
    nj = nk = m // bm
    return pl.pallas_call(
        partial(_quadform_kernel, nj=nj, nk=nk, bf16=bf16),
        grid=(n // bn, nk, nj),
        in_specs=[
            pl.BlockSpec((bn, bm), lambda i, k, j: (i, j)),  # G[:, j]
            pl.BlockSpec((bm, bm), lambda i, k, j: (j, k)),  # W[j, k]
            pl.BlockSpec((bn, bm), lambda i, k, j: (i, k)),  # G[:, k]
        ],
        out_specs=pl.BlockSpec((bn,), lambda i, k, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bn, bm), jnp.float32)],
        interpret=interpret,
    )(g, w, g)
