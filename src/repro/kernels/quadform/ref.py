"""Pure-jnp oracle for the quadform kernel."""
import jax
import jax.numpy as jnp


def quadform_ref(g: jax.Array, w: jax.Array) -> jax.Array:
    g32 = g.astype(jnp.float32)
    w32 = w.astype(jnp.float32)
    return jnp.sum((g32 @ w32) * g32, axis=1)
