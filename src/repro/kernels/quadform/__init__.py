from . import ops
