"""Pallas TPU kernels: the fused FALKON K_nM contractions (multi-RHS panels).

Three operators share one tile schedule — each (bn, d) tile of X is streamed
HBM->VMEM exactly once, the Gram tile G = k(X_tile, Z) is built in VMEM, and
the contraction epilogue runs before the tile is discarded:

  * ``falkon_matvec_pallas``  R = K_nM^T (K_nM V)  — the CG quadratic matvec
  * ``knm_t_pallas``          R = K_nM^T Y         — the CG right-hand sides
  * ``knm_matvec_pallas``     R = K_nM V           — predict / KRR forward

All three take (·, kp) *panels* (the multi-RHS block-CG form; kp is the
lane-padded column count, 128-aligned): the Gram tile — the expensive part,
one MXU matmul plus the VPU distance/exp epilogue per (bn, M) block — is
built once per tile and contracted against every column in the MXU epilogue,
so extra right-hand sides add only (bn, M) x (M, kp) GEMM flops. A single
RHS is the kp = 128 panel with one live column (ops.py pads/slices).

On GPU the reference FALKON implementation materializes K_nM block-by-block
in HBM and runs two GEMVs per block (arithmetic intensity ~4 FLOP/B on the
second pass). Fusing keeps HBM traffic at n*d reads + n*kp (or M*kp) writes
total, so the kernels are MXU-bound for M >= ~256 (DESIGN.md §2).

Grid (n/bn,): Z (M, d) and the (M, kp) panel are VMEM-resident across the
whole sweep (M*(d+kp) <= ~4M floats for the paper's d_eff-sized center
sets). The reductions (``falkon_matvec``/``knm_t``) revisit one (M, kp)
output block every step and accumulate; ``knm_matvec`` writes a private
(bn, kp) block per step.

Mixed precision (``bf16=True``): the Gram tile's dominant (bn, d) x (d, M)
product loads its operands as bf16 and accumulates on the MXU in fp32
(``preferred_element_type``); the row norms, distance epilogue, exp, and the
second-stage contractions all stay fp32. See DESIGN.md §2 for the measured
parity tolerances (kernel values ~1e-2 relative on unit-scale data).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ...families import get_family


def _gram_tile(x: jax.Array, z: jax.Array, *, kind: str, inv_scale: float,
               bf16: bool) -> jax.Array:
    """k(X_tile, Z) in VMEM; x (bn, d) and z (M, d) are fp32.

    ``kind`` names a registered kernel family; its elementwise epilogue runs
    here on the VPU (the same function body as the jnp reference). With
    ``bf16`` the MXU product takes bf16 operands (fp32 accumulation); the
    norms and epilogue are always fp32 so the only precision loss is the
    cross-term of the squared distance.
    """
    fam = get_family(kind)
    xc, zc = (x.astype(jnp.bfloat16), z.astype(jnp.bfloat16)) if bf16 else (x, z)
    prod = jax.lax.dot_general(xc, zc, (((1,), (1,)), ((), ())),
                               preferred_element_type=jnp.float32)  # (bn, M)
    if fam.dot_only:
        return fam.epilogue(prod, inv_scale)
    d2 = jnp.maximum(jnp.sum(x * x, -1)[:, None] + jnp.sum(z * z, -1)[None, :]
                     - 2.0 * prod, 0.0)
    return fam.epilogue(d2, inv_scale)


def _panel_t_g(g: jax.Array, t: jax.Array) -> jax.Array:
    """G^T T: contract the shared (bn,) tile axis — (bn, M) x (bn, kp) ->
    (M, kp), fp32 MXU accumulation."""
    return jax.lax.dot_general(g, t, (((0,), (0,)), ((), ())),
                               preferred_element_type=jnp.float32)


def _matvec_kernel(x_ref, z_ref, v_ref, o_ref, *, kind: str, inv_scale: float,
                   bn: int, n_valid: int, bf16: bool):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...].astype(jnp.float32)  # (bn, d)
    z = z_ref[...].astype(jnp.float32)  # (M, d)
    g = _gram_tile(x, z, kind=kind, inv_scale=inv_scale, bf16=bf16)
    rows = i * bn + jax.lax.broadcasted_iota(jnp.int32, (bn, 1), 0)
    g = jnp.where(rows < n_valid, g, 0.0)  # padded X rows contribute nothing
    t = g @ v_ref[...].astype(jnp.float32)  # (bn, kp): one G, every column
    o_ref[...] += _panel_t_g(g, t)  # G^T T, still in VMEM


@partial(jax.jit, static_argnames=("kind", "bn", "n_valid", "interpret",
                                   "inv_scale", "bf16"))
def falkon_matvec_pallas(x: jax.Array, z: jax.Array, v: jax.Array, inv_scale: float,
                         *, kind: str = "gaussian", bn: int = 512, n_valid: int,
                         interpret: bool = True, bf16: bool = False) -> jax.Array:
    """K_nM^T K_nM V for pre-padded x (n, d), z (M, d), V (M, kp)."""
    n, d = x.shape
    m, kp = z.shape[0], v.shape[1]
    assert n % bn == 0 and d % 128 == 0 and m % 128 == 0 and kp % 128 == 0
    return pl.pallas_call(
        partial(_matvec_kernel, kind=kind, inv_scale=float(inv_scale), bn=bn,
                n_valid=n_valid, bf16=bf16),
        grid=(n // bn,),
        in_specs=[
            pl.BlockSpec((bn, d), lambda i: (i, 0)),
            pl.BlockSpec((m, d), lambda i: (0, 0)),
            pl.BlockSpec((m, kp), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((m, kp), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((m, kp), jnp.float32),
        interpret=interpret,
    )(x, z, v)


def _masked_matvec_kernel(x_ref, z_ref, v_ref, m_ref, o_ref, *, kind: str,
                          inv_scale: float, bn: int, n_valid: int, bf16: bool):
    """The quadratic matvec with a per-column row-mask panel (exact-CV CG):
    column j accumulates G^T diag(m_j) G v_j. Identical tile schedule to
    ``_matvec_kernel`` plus one VPU multiply on the (bn, kp) intermediate —
    the mask tile rides the same HBM->VMEM stream as X."""
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...].astype(jnp.float32)  # (bn, d)
    z = z_ref[...].astype(jnp.float32)  # (M, d)
    g = _gram_tile(x, z, kind=kind, inv_scale=inv_scale, bf16=bf16)
    rows = i * bn + jax.lax.broadcasted_iota(jnp.int32, (bn, 1), 0)
    g = jnp.where(rows < n_valid, g, 0.0)
    t = g @ v_ref[...].astype(jnp.float32)  # (bn, kp)
    t = t * m_ref[...].astype(jnp.float32)  # per-column row exclusion
    o_ref[...] += _panel_t_g(g, t)


@partial(jax.jit, static_argnames=("kind", "bn", "n_valid", "interpret",
                                   "inv_scale", "bf16"))
def falkon_matvec_masked_pallas(x: jax.Array, z: jax.Array, v: jax.Array,
                                mask: jax.Array, inv_scale: float, *,
                                kind: str = "gaussian", bn: int = 512,
                                n_valid: int, interpret: bool = True,
                                bf16: bool = False) -> jax.Array:
    """K_nM^T diag(m_j) K_nM V per column, pre-padded; mask (n, kp)."""
    n, d = x.shape
    m, kp = z.shape[0], v.shape[1]
    assert n % bn == 0 and d % 128 == 0 and m % 128 == 0 and kp % 128 == 0
    assert mask.shape == (n, kp)
    return pl.pallas_call(
        partial(_masked_matvec_kernel, kind=kind, inv_scale=float(inv_scale),
                bn=bn, n_valid=n_valid, bf16=bf16),
        grid=(n // bn,),
        in_specs=[
            pl.BlockSpec((bn, d), lambda i: (i, 0)),
            pl.BlockSpec((m, d), lambda i: (0, 0)),
            pl.BlockSpec((m, kp), lambda i: (0, 0)),
            pl.BlockSpec((bn, kp), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((m, kp), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((m, kp), jnp.float32),
        interpret=interpret,
    )(x, z, v, mask)


def _knm_t_kernel(x_ref, z_ref, y_ref, o_ref, *, kind: str, inv_scale: float,
                  bn: int, n_valid: int, bf16: bool):
    """R += k(X_tile, Z)^T Y_tile — the CG right-hand sides K_nM^T Y, fused."""
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...].astype(jnp.float32)  # (bn, d)
    z = z_ref[...].astype(jnp.float32)  # (M, d)
    g = _gram_tile(x, z, kind=kind, inv_scale=inv_scale, bf16=bf16)
    rows = i * bn + jax.lax.broadcasted_iota(jnp.int32, (bn, 1), 0)
    g = jnp.where(rows < n_valid, g, 0.0)
    o_ref[...] += _panel_t_g(g, y_ref[...].astype(jnp.float32))  # (M, kp)


@partial(jax.jit, static_argnames=("kind", "bn", "n_valid", "interpret",
                                   "inv_scale", "bf16"))
def knm_t_pallas(x: jax.Array, z: jax.Array, y: jax.Array, inv_scale: float,
                 *, kind: str = "gaussian", bn: int = 512, n_valid: int,
                 interpret: bool = True, bf16: bool = False) -> jax.Array:
    """K_nM^T Y for pre-padded x (n, d), z (M, d), Y (n, kp)."""
    n, d = x.shape
    m, kp = z.shape[0], y.shape[1]
    assert n % bn == 0 and d % 128 == 0 and m % 128 == 0 and kp % 128 == 0
    return pl.pallas_call(
        partial(_knm_t_kernel, kind=kind, inv_scale=float(inv_scale), bn=bn,
                n_valid=n_valid, bf16=bf16),
        grid=(n // bn,),
        in_specs=[
            pl.BlockSpec((bn, d), lambda i: (i, 0)),
            pl.BlockSpec((m, d), lambda i: (0, 0)),
            pl.BlockSpec((bn, kp), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((m, kp), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((m, kp), jnp.float32),
        interpret=interpret,
    )(x, z, y)


def _knm_matvec_kernel(x_ref, z_ref, a_ref, o_ref, *, kind: str,
                       inv_scale: float, bf16: bool):
    """O_tile = k(X_tile, Z) A — the predict / KRR forward contraction.

    No cross-step accumulation: each grid step owns its (bn, kp) output
    block, so no init/revisit protocol is needed. Padded X rows produce
    garbage that ops.py slices off; padded Z rows meet A's zero padding.
    """
    x = x_ref[...].astype(jnp.float32)  # (bn, d)
    z = z_ref[...].astype(jnp.float32)  # (M, d)
    g = _gram_tile(x, z, kind=kind, inv_scale=inv_scale, bf16=bf16)
    o_ref[...] = g @ a_ref[...].astype(jnp.float32)  # (bn, kp)


@partial(jax.jit, static_argnames=("kind", "bn", "interpret", "inv_scale", "bf16"))
def knm_matvec_pallas(x: jax.Array, z: jax.Array, alpha: jax.Array, inv_scale: float,
                      *, kind: str = "gaussian", bn: int = 512,
                      interpret: bool = True, bf16: bool = False) -> jax.Array:
    """K_nM A for pre-padded x (n, d), z (M, d), A (M, kp)."""
    n, d = x.shape
    m, kp = z.shape[0], alpha.shape[1]
    assert n % bn == 0 and d % 128 == 0 and m % 128 == 0 and kp % 128 == 0
    return pl.pallas_call(
        partial(_knm_matvec_kernel, kind=kind, inv_scale=float(inv_scale), bf16=bf16),
        grid=(n // bn,),
        in_specs=[
            pl.BlockSpec((bn, d), lambda i: (i, 0)),
            pl.BlockSpec((m, d), lambda i: (0, 0)),
            pl.BlockSpec((m, kp), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bn, kp), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, kp), jnp.float32),
        interpret=interpret,
    )(x, z, alpha)
