"""Pallas TPU kernel: fused FALKON CG matvec  r = K_nM^T (K_nM v).

The O(n M d + n M) inner loop of every FALKON CG iteration. On GPU the
reference FALKON implementation materializes K_nM block-by-block in HBM and
runs two GEMVs per block (arithmetic intensity ~4 FLOP/B on the second
pass). Here each (bn, d) tile of X is streamed HBM->VMEM exactly once; the
Gram tile G = k(X_tile, Z), t = G v and r += G^T t all happen in VMEM, so
HBM traffic is n*d reads + M writes total — the kernel is MXU-bound for
M >= ~256 (DESIGN.md §2).

Grid (n/bn,): Z (M, d) and v (M,) are VMEM-resident across the whole sweep
(M*d <= ~4M floats for the paper's d_eff-sized center sets); the (M,) output
block is revisited every step and accumulated.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matvec_kernel(x_ref, z_ref, v_ref, o_ref, *, kind: str, inv_scale: float,
                   bn: int, n_valid: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...].astype(jnp.float32)  # (bn, d)
    z = z_ref[...].astype(jnp.float32)  # (M, d)
    prod = jax.lax.dot_general(x, z, (((1,), (1,)), ((), ())),
                               preferred_element_type=jnp.float32)  # (bn, M)
    if kind == "linear":
        g = prod
    else:
        d2 = jnp.maximum(jnp.sum(x * x, -1)[:, None] + jnp.sum(z * z, -1)[None, :]
                         - 2.0 * prod, 0.0)
        g = jnp.exp(-d2 * inv_scale) if kind == "gaussian" else jnp.exp(
            -jnp.sqrt(d2 + 1e-30) * inv_scale)
    rows = i * bn + jax.lax.broadcasted_iota(jnp.int32, (bn, 1), 0)
    g = jnp.where(rows < n_valid, g, 0.0)  # padded X rows contribute nothing
    t = g @ v_ref[...].astype(jnp.float32)  # (bn,)
    o_ref[...] += t @ g  # G^T t, still in VMEM


@partial(jax.jit, static_argnames=("kind", "bn", "n_valid", "interpret", "inv_scale"))
def falkon_matvec_pallas(x: jax.Array, z: jax.Array, v: jax.Array, inv_scale: float,
                         *, kind: str = "gaussian", bn: int = 512, n_valid: int,
                         interpret: bool = True) -> jax.Array:
    """K_nM^T K_nM v for pre-padded x (n, d), z (M, d), v (M,)."""
    n, d = x.shape
    m = z.shape[0]
    assert n % bn == 0 and d % 128 == 0 and m % 128 == 0
    return pl.pallas_call(
        partial(_matvec_kernel, kind=kind, inv_scale=float(inv_scale), bn=bn,
                n_valid=n_valid),
        grid=(n // bn,),
        in_specs=[
            pl.BlockSpec((bn, d), lambda i: (i, 0)),
            pl.BlockSpec((m, d), lambda i: (0, 0)),
            pl.BlockSpec((m,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((m,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((m,), jnp.float32),
        interpret=interpret,
    )(x, z, v)


def _knm_t_kernel(x_ref, z_ref, y_ref, o_ref, *, kind: str, inv_scale: float,
                  bn: int, n_valid: int):
    """r += y_tile^T k(X_tile, Z) — the CG right-hand side K_nM^T y, fused.

    Same tile schedule as the quadratic matvec: the Gram tile never leaves
    VMEM, so building b costs one streaming pass over X instead of a
    materialized (n, M) Gram plus a GEMV.
    """
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...].astype(jnp.float32)  # (bn, d)
    z = z_ref[...].astype(jnp.float32)  # (M, d)
    prod = jax.lax.dot_general(x, z, (((1,), (1,)), ((), ())),
                               preferred_element_type=jnp.float32)  # (bn, M)
    if kind == "linear":
        g = prod
    else:
        d2 = jnp.maximum(jnp.sum(x * x, -1)[:, None] + jnp.sum(z * z, -1)[None, :]
                         - 2.0 * prod, 0.0)
        g = jnp.exp(-d2 * inv_scale) if kind == "gaussian" else jnp.exp(
            -jnp.sqrt(d2 + 1e-30) * inv_scale)
    rows = i * bn + jax.lax.broadcasted_iota(jnp.int32, (bn, 1), 0)
    g = jnp.where(rows < n_valid, g, 0.0)
    o_ref[...] += y_ref[...].astype(jnp.float32) @ g  # (bn,) @ (bn, M)


@partial(jax.jit, static_argnames=("kind", "bn", "n_valid", "interpret", "inv_scale"))
def knm_t_pallas(x: jax.Array, z: jax.Array, y: jax.Array, inv_scale: float,
                 *, kind: str = "gaussian", bn: int = 512, n_valid: int,
                 interpret: bool = True) -> jax.Array:
    """K_nM^T y for pre-padded x (n, d), z (M, d), y (n,)."""
    n, d = x.shape
    m = z.shape[0]
    assert n % bn == 0 and d % 128 == 0 and m % 128 == 0
    return pl.pallas_call(
        partial(_knm_t_kernel, kind=kind, inv_scale=float(inv_scale), bn=bn,
                n_valid=n_valid),
        grid=(n // bn,),
        in_specs=[
            pl.BlockSpec((bn, d), lambda i: (i, 0)),
            pl.BlockSpec((m, d), lambda i: (0, 0)),
            pl.BlockSpec((bn,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((m,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((m,), jnp.float32),
        interpret=interpret,
    )(x, z, y)
