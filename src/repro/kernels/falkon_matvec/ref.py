"""Pure-jnp oracle for the fused FALKON matvec.

Shapes are generic over the trailing axis: v / y / alpha may be single
vectors or (·, k) multi-RHS panels, exactly like the Pallas kernels.
"""
import jax
import jax.numpy as jnp

from ..gram.ref import gram_ref


def falkon_matvec_ref(x: jax.Array, z: jax.Array, v: jax.Array, inv_scale: float,
                      *, kind: str = "gaussian") -> jax.Array:
    g = gram_ref(x, z, inv_scale, kind=kind).astype(jnp.float32)
    return g.T @ (g @ v.astype(jnp.float32))


def falkon_matvec_masked_ref(x: jax.Array, z: jax.Array, v: jax.Array,
                             mask: jax.Array, inv_scale: float,
                             *, kind: str = "gaussian") -> jax.Array:
    """Column j of the masked quadratic matvec: G^T diag(mask[:, j]) G v_j.
    ``mask`` is (n,) for a vector v or (n, k) for a panel."""
    g = gram_ref(x, z, inv_scale, kind=kind).astype(jnp.float32)
    t = g @ v.astype(jnp.float32)
    return g.T @ (t * mask.astype(jnp.float32))


def knm_t_ref(x: jax.Array, z: jax.Array, y: jax.Array, inv_scale: float,
              *, kind: str = "gaussian") -> jax.Array:
    g = gram_ref(x, z, inv_scale, kind=kind).astype(jnp.float32)
    return g.T @ y.astype(jnp.float32)


def knm_matvec_ref(x: jax.Array, z: jax.Array, alpha: jax.Array, inv_scale: float,
                   *, kind: str = "gaussian") -> jax.Array:
    g = gram_ref(x, z, inv_scale, kind=kind).astype(jnp.float32)
    return g @ alpha.astype(jnp.float32)
