from . import ops
