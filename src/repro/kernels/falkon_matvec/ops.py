"""Public wrappers for the fused FALKON CG contractions.

``falkon_matvec`` (K_nM^T K_nM v) and ``knm_t`` (K_nM^T y) are the two
operators ``repro.core.backend.PallasBackend`` serves to
``repro.core.falkon.falkon_fit``; both pad internally to tile boundaries.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..common import default_interpret, pad_dim, round_up
from .falkon_matvec import falkon_matvec_pallas, knm_t_pallas
from .ref import falkon_matvec_ref, knm_t_ref


def falkon_matvec(x: jax.Array, z: jax.Array, v: jax.Array, sigma: float = 1.0, *,
                  kind: str = "gaussian", bn: int = 512,
                  interpret: bool | None = None) -> jax.Array:
    """K_nM^T (K_nM v) -> (M,) fp32. Arbitrary shapes, padded internally."""
    inv_scale = {"gaussian": 1.0 / (2.0 * sigma**2), "laplacian": 1.0 / sigma}.get(kind, 1.0)
    n, d = x.shape
    m = z.shape[0]
    interpret = default_interpret() if interpret is None else interpret
    dp = round_up(d, 128)
    xp = pad_dim(pad_dim(x, 0, round_up(n, bn)), 1, dp)
    zp = pad_dim(pad_dim(z, 0, round_up(m, 128)), 1, dp)
    # padded Z rows are the all-zeros point; its kernel values are nonzero but
    # v is zero-padded so they never enter t, and we slice r back to (m,).
    vp = pad_dim(v, 0, round_up(m, 128))
    out = falkon_matvec_pallas(xp, zp, vp, float(inv_scale), kind=kind, bn=bn,
                               n_valid=n, interpret=interpret)
    return out[:m]


def make_knm_quadratic_op(x: jax.Array, z: jax.Array, sigma: float = 1.0, *,
                          kind: str = "gaussian", bn: int = 512,
                          interpret: bool | None = None):
    def op(v: jax.Array) -> jax.Array:
        return falkon_matvec(x, z, v, sigma, kind=kind, bn=bn, interpret=interpret)

    return op


def knm_t(x: jax.Array, z: jax.Array, y: jax.Array, sigma: float = 1.0, *,
          kind: str = "gaussian", bn: int = 512,
          interpret: bool | None = None) -> jax.Array:
    """K_nM^T y -> (M,) fp32. Arbitrary shapes, padded internally."""
    inv_scale = {"gaussian": 1.0 / (2.0 * sigma**2), "laplacian": 1.0 / sigma}.get(kind, 1.0)
    n, d = x.shape
    m = z.shape[0]
    interpret = default_interpret() if interpret is None else interpret
    dp = round_up(d, 128)
    xp = pad_dim(pad_dim(x, 0, round_up(n, bn)), 1, dp)
    zp = pad_dim(pad_dim(z, 0, round_up(m, 128)), 1, dp)
    yp = pad_dim(y, 0, round_up(n, bn))
    out = knm_t_pallas(xp, zp, yp, float(inv_scale), kind=kind, bn=bn,
                       n_valid=n, interpret=interpret)
    return out[:m]


falkon_matvec_reference = falkon_matvec_ref
knm_t_reference = knm_t_ref
