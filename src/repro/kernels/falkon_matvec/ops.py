"""Public wrappers for the fused FALKON K_nM contractions.

``falkon_matvec`` (K_nM^T K_nM V), ``knm_t`` (K_nM^T Y) and ``knm_matvec``
(K_nM V — predict / KRR forward) are the operators
``repro.core.backend.PallasBackend`` serves to ``repro.core.falkon``; all
pad internally to tile boundaries. Every wrapper accepts a single vector
(the classic FALKON shapes) or an (·, k) multi-RHS panel; panels are padded
up to the 128-lane tile width, streamed through the panel kernels in
falkon_matvec.py — one Gram tile evaluation for every column — and sliced
back. ``bf16=True`` selects the mixed-precision tile path (bf16 MXU
operands, fp32 accumulation — see falkon_matvec.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ...families import get_family
from ..common import default_interpret, pad_dim, round_up
from .falkon_matvec import (falkon_matvec_masked_pallas, falkon_matvec_pallas,
                            knm_matvec_pallas, knm_t_pallas)
from .ref import (falkon_matvec_masked_ref, falkon_matvec_ref, knm_matvec_ref,
                  knm_t_ref)


def _inv_scale(kind: str, sigma: float) -> float:
    """The family's epilogue scalar — resolved from the registry, so every
    registered family (incl. matern32 / cauchy) flows through unchanged."""
    return float(get_family(kind).inv_scale(sigma))


def _as_panel(v: jax.Array) -> tuple[jax.Array, bool]:
    """(lane-padded (·, kp) panel, was_vector) for a (·,) or (·, k) input."""
    squeeze = v.ndim == 1
    vp = v[:, None] if squeeze else v
    return pad_dim(vp, 1, round_up(vp.shape[1], 128)), squeeze


def _unpanel(out: jax.Array, k_or_none: int | None) -> jax.Array:
    """Slice the lane padding back off; ``None`` restores a vector."""
    return out[:, 0] if k_or_none is None else out[:, :k_or_none]


def falkon_matvec(x: jax.Array, z: jax.Array, v: jax.Array, sigma: float = 1.0, *,
                  kind: str = "gaussian", bn: int = 512,
                  interpret: bool | None = None, bf16: bool = False,
                  mask: jax.Array | None = None) -> jax.Array:
    """K_nM^T (K_nM v) -> (M,) or (M, k) fp32. Arbitrary shapes, padded
    internally; a panel ``v`` is the multi-RHS block-CG iterate.

    ``mask`` — optional per-column row-exclusion weights shaped like a
    length-n slice of ``v``'s panel-ness ((n,) with a vector, (n, k) with a
    panel): column j computes K_nM^T diag(m_j) K_nM v_j via the masked
    kernel variant (one extra VPU multiply per tile). ``mask=None``
    dispatches the original kernel unchanged."""
    n, d = x.shape
    m = z.shape[0]
    interpret = default_interpret() if interpret is None else interpret
    dp = round_up(d, 128)
    xp = pad_dim(pad_dim(x, 0, round_up(n, bn)), 1, dp)
    zp = pad_dim(pad_dim(z, 0, round_up(m, 128)), 1, dp)
    # padded Z rows are the all-zeros point; its kernel values are nonzero but
    # v is zero-padded so they never enter t, and we slice r back to (m,).
    vp, squeeze = _as_panel(pad_dim(v, 0, round_up(m, 128)))
    if mask is None:
        out = falkon_matvec_pallas(xp, zp, vp, float(_inv_scale(kind, sigma)),
                                   kind=kind, bn=bn, n_valid=n,
                                   interpret=interpret, bf16=bf16)
        return _unpanel(out[:m], None if squeeze else v.shape[1])
    # zero-padded mask rows/columns: padded rows are killed by n_valid anyway
    # and padded v columns are zero, so the pad value never reaches the output.
    if mask.ndim == 1 and v.ndim == 2:
        mask = jnp.broadcast_to(mask[:, None], (n, v.shape[1]))
    mp, _ = _as_panel(pad_dim(mask.astype(x.dtype), 0, round_up(n, bn)))
    out = falkon_matvec_masked_pallas(xp, zp, vp, mp,
                                      float(_inv_scale(kind, sigma)), kind=kind,
                                      bn=bn, n_valid=n, interpret=interpret,
                                      bf16=bf16)
    return _unpanel(out[:m], None if squeeze else v.shape[1])


def make_knm_quadratic_op(x: jax.Array, z: jax.Array, sigma: float = 1.0, *,
                          kind: str = "gaussian", bn: int = 512,
                          interpret: bool | None = None, bf16: bool = False,
                          mask: jax.Array | None = None):
    """Close over (x, z) -> the CG quadratic operator ``falkon_matvec``;
    an optional ``mask`` panel selects the masked kernel (exact-CV CG)."""
    def op(v: jax.Array) -> jax.Array:
        return falkon_matvec(x, z, v, sigma, kind=kind, bn=bn, interpret=interpret,
                             bf16=bf16, mask=mask)

    return op


def knm_t(x: jax.Array, z: jax.Array, y: jax.Array, sigma: float = 1.0, *,
          kind: str = "gaussian", bn: int = 512,
          interpret: bool | None = None, bf16: bool = False,
          mask: jax.Array | None = None) -> jax.Array:
    """K_nM^T y -> (M,) or (M, k) fp32. Arbitrary shapes, padded internally;
    a panel ``y`` yields every CG right-hand side from one X sweep. A
    ``mask`` shaped like ``y`` folds into the targets (K_nM^T (mask * y))
    before the sweep — the mask enters linearly, so no kernel variant is
    needed."""
    if mask is not None:
        y = y * mask.astype(y.dtype)
    n, d = x.shape
    m = z.shape[0]
    interpret = default_interpret() if interpret is None else interpret
    dp = round_up(d, 128)
    xp = pad_dim(pad_dim(x, 0, round_up(n, bn)), 1, dp)
    zp = pad_dim(pad_dim(z, 0, round_up(m, 128)), 1, dp)
    yp, squeeze = _as_panel(pad_dim(y, 0, round_up(n, bn)))
    out = knm_t_pallas(xp, zp, yp, float(_inv_scale(kind, sigma)), kind=kind, bn=bn,
                       n_valid=n, interpret=interpret, bf16=bf16)
    return _unpanel(out[:m], None if squeeze else y.shape[1])


def knm_matvec(x: jax.Array, z: jax.Array, alpha: jax.Array, sigma: float = 1.0, *,
               kind: str = "gaussian", bn: int = 512,
               interpret: bool | None = None, bf16: bool = False) -> jax.Array:
    """K_nM alpha -> (n,) or (n, k) fp32 — the predict contraction, fused in
    VMEM; an (M, k) ``alpha`` panel serves multi-output predict with one
    kernel evaluation."""
    n, d = x.shape
    m = z.shape[0]
    interpret = default_interpret() if interpret is None else interpret
    dp = round_up(d, 128)
    xp = pad_dim(pad_dim(x, 0, round_up(n, bn)), 1, dp)
    zp = pad_dim(pad_dim(z, 0, round_up(m, 128)), 1, dp)
    # zero alpha on padded Z rows
    ap, squeeze = _as_panel(pad_dim(alpha, 0, round_up(m, 128)))
    out = knm_matvec_pallas(xp, zp, ap, float(_inv_scale(kind, sigma)), kind=kind,
                            bn=bn, interpret=interpret, bf16=bf16)
    return _unpanel(out[:n], None if squeeze else alpha.shape[1])


falkon_matvec_reference = falkon_matvec_ref
falkon_matvec_masked_reference = falkon_matvec_masked_ref
knm_t_reference = knm_t_ref
knm_matvec_reference = knm_matvec_ref
