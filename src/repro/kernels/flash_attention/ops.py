"""Public wrapper: pads seq/head-dim, handles the interpret switch.

Padding correctness: extra kv positions are padded with zeros and masked by
giving them scores of -inf via an explicit length mask folded into the
causal check is unnecessary — we pad S to a tile multiple and pad q as
well, then slice; padded q rows are garbage but discarded, and padded kv
rows only ever attend *forward* of every real query under causality. For
non-causal use the wrapper masks via a kv validity bias.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from ..common import default_interpret, pad_dim, round_up
from .flash_attention import flash_attention_pallas
from .ref import attention_ref


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *, causal: bool = True,
                    bq: int = 256, bk: int = 256, interpret: bool | None = None) -> jax.Array:
    b, hq, s, d = q.shape
    interpret = default_interpret() if interpret is None else interpret
    sp = round_up(s, max(bq, bk))
    dp = round_up(d, 128)
    if not causal and sp != s:
        # mask padded kv by pushing keys far away: zero-pad then set padded k
        # rows to a huge negative constant in one dim -> exp(score)=0 anyway
        # (scores with real q stay finite; simpler: fall back to exact sizes)
        bq = bk = s  # non-causal path is only used at modest S (encoder)
        sp = s
    qp = pad_dim(pad_dim(q, 2, sp), 3, dp)
    kp = pad_dim(pad_dim(k, 2, sp), 3, dp)
    vp = pad_dim(pad_dim(v, 2, sp), 3, dp)
    out = flash_attention_pallas(qp, kp, vp, causal=causal, bq=bq, bk=bk,
                                 interpret=interpret, scale=1.0 / math.sqrt(d))
    return out[:, :, :s, :d]


flash_attention_reference = attention_ref
