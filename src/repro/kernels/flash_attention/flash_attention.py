"""Pallas TPU kernel: causal GQA flash attention (forward).

The LM stack's compute hot-spot at prefill (train_4k / prefill_32k shapes).
Standard streaming-softmax tiling adapted to TPU: grid (batch, q_head,
q_block, kv_block) with kv innermost; running (m, l, acc) state lives in
VMEM scratch and survives the sequential kv sweep; the output block
(indexed by b, h, i only) is written in the kv-epilogue. GQA is expressed
purely in the K/V index_map (q head h reads kv head h // group) so no
KV replication ever hits HBM.

Causality prunes entire kv blocks (pl.when(j <= i_hi)) rather than only
masking inside the tile — half the sweep is skipped at train shapes.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, bq: int, bk: int, nk: int, causal: bool):
    i = pl.program_id(2)
    j = pl.program_id(3)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    def body():
        q = q_ref[0, 0].astype(jnp.float32) * scale  # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)  # (bk, d)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)  # (bq, bk)
        if causal:
            qpos = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            kpos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(qpos >= kpos, s, _NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = corr * l_ref[...] + jnp.sum(p, axis=1)
        acc_ref[...] = corr[:, None] * acc_ref[...] + p @ v_ref[0, 0].astype(jnp.float32)
        m_ref[...] = m_new

    if causal:
        # skip kv blocks strictly above the diagonal
        pl.when(j * bk <= i * bq + bq - 1)(body)
    else:
        body()

    @pl.when(j == nk - 1)
    def _epilogue():
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[:, None]).astype(o_ref.dtype)


@partial(jax.jit, static_argnames=("causal", "bq", "bk", "interpret", "scale"))
def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *, causal: bool = True,
                           bq: int = 256, bk: int = 256, interpret: bool = True,
                           scale: float | None = None) -> jax.Array:
    """q (B, Hq, S, D), k/v (B, Hkv, S, D) pre-padded: S % bq == S % bk == 0,
    D % 128 == 0, Hq % Hkv == 0. ``scale`` must reflect the *unpadded* head
    dim. Returns (B, Hq, S, D)."""
    b, hq, s, d = q.shape
    hkv = k.shape[1]
    assert s % bq == 0 and s % bk == 0 and hq % hkv == 0
    group = hq // hkv
    nk = s // bk
    scale = 1.0 / math.sqrt(d) if scale is None else scale
    return pl.pallas_call(
        partial(_flash_kernel, scale=scale, bq=bq, bk=bk, nk=nk, causal=causal),
        grid=(b, hq, s // bq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda b, h, i, j: (b, h // group, j, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda b, h, i, j: (b, h // group, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
