"""Pure-jnp oracle: exact softmax attention with GQA head sharing."""
import math

import jax
import jax.numpy as jnp


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *, causal: bool = True) -> jax.Array:
    b, hq, s, d = q.shape
    hkv = k.shape[1]
    group = hq // hkv
    kf = jnp.repeat(k, group, axis=1).astype(jnp.float32)
    vf = jnp.repeat(v, group, axis=1).astype(jnp.float32)
    scores = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), kf) / math.sqrt(d)
    if causal:
        mask = jnp.tril(jnp.ones((s, s), bool))
        scores = jnp.where(mask, scores, -jnp.inf)
    p = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vf).astype(q.dtype)
