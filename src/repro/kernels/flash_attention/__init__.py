from . import ops
