from . import ops
