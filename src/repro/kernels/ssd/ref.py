"""Oracle: the pure-jnp chunked SSD from the model stack (itself validated
against the naive recurrence in tests/test_ssd.py)."""
import jax

from ...models.mamba2 import ssd_chunked


def ssd_ref(x: jax.Array, dt: jax.Array, a: jax.Array, b: jax.Array, c: jax.Array,
            *, chunk: int = 128):
    y, state = ssd_chunked(x, dt, a, b[:, :, None, :], c[:, :, None, :], chunk=chunk)
    return y, state
