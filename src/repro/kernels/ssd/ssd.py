"""Pallas TPU kernel: Mamba-2 SSD chunk scan (fused).

One grid step processes one (batch, chunk) tile: the intra-chunk decay
tensor L = exp(segsum(dt*A)), the CB^T "attention-like" term, the chunk
state build and the inter-chunk output all stay in VMEM; the recurrent
(H, P, N) state is carried across the sequential chunk dimension in VMEM
scratch, so HBM sees only x/dt/B/C reads and y writes — the chunked-SSD
algorithm's O(S*Q) intermediates never materialize in HBM.

All four heavy contractions are head-batched dot_generals (MXU):
  y_diag[h] = (CB ⊙ L[h]) @ (dt x)[h]         (Q,Q)@(Q,P)
  state[h] += (dt x decay_end)[h]^T @ B        (P,Q)@(Q,N)
  y_off[h]  = decay_in ⊙ (C @ state_in[h]^T)   (Q,N)@(N,P)

Grid (B, S/Q), chunk dim innermost (sequential carry).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, state_out_ref, state_ref,
                *, nc: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0].astype(jnp.float32)  # (Q, H, P)
    dt = dt_ref[0].astype(jnp.float32)  # (Q, H)
    a = a_ref[...].astype(jnp.float32)  # (H,)
    bmat = b_ref[0].astype(jnp.float32)  # (Q, N)
    cmat = c_ref[0].astype(jnp.float32)  # (Q, N)

    da = dt * a[None, :]  # (Q, H) log-decay increments
    cum = jnp.cumsum(da, axis=0)  # (Q, H)
    # L[h, q, k] = exp(cum[q,h] - cum[k,h]) for k <= q
    diff = cum.T[:, :, None] - cum.T[:, None, :]  # (H, Q, Q)
    q = x.shape[0]
    mask = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    l_dec = jnp.where(mask[None], jnp.exp(diff), 0.0)  # (H, Q, Q)

    cb = jax.lax.dot_general(cmat, bmat, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (Q, Q)
    dtx = (dt[:, :, None] * x).transpose(1, 0, 2)  # (H, Q, P)
    m = cb[None] * l_dec  # (H, Q, Q)
    y_diag = jax.lax.dot_general(m, dtx, (((2,), (1,)), ((0,), (0,))),
                                 preferred_element_type=jnp.float32)  # (H, Q, P)

    st_in = state_ref[...]  # (H, P, N)
    dec_in = jnp.exp(cum).T  # (H, Q) decay from chunk start to q (inclusive)
    cs = jax.lax.dot_general(
        jnp.broadcast_to(cmat[None], (st_in.shape[0],) + cmat.shape), st_in,
        (((2,), (2,)), ((0,), (0,))), preferred_element_type=jnp.float32)  # (H, Q, P)
    y_off = dec_in[:, :, None] * cs

    y_ref[0] = (y_diag + y_off).transpose(1, 0, 2).astype(y_ref.dtype)  # (Q, H, P)

    # state update: decay-to-end-weighted inputs + decayed carry
    total = cum[-1]  # (H,)
    dec_end = jnp.exp(total[None, :] - cum)  # (Q, H)
    w = (dtx.transpose(0, 2, 1) * dec_end.T[:, None, :])  # (H, P, Q)
    st_new = jax.lax.dot_general(
        w, jnp.broadcast_to(bmat[None], (w.shape[0],) + bmat.shape),
        (((2,), (1,)), ((0,), (0,))), preferred_element_type=jnp.float32)  # (H, P, N)
    state_ref[...] = jnp.exp(total)[:, None, None] * st_in + st_new

    @pl.when(ci == nc - 1)
    def _emit():
        state_out_ref[0] = state_ref[...]


@partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_pallas(x: jax.Array, dt: jax.Array, a: jax.Array, b: jax.Array, c: jax.Array,
               *, chunk: int = 128, interpret: bool = True) -> tuple[jax.Array, jax.Array]:
    """x (B,S,H,P), dt (B,S,H) [post-softplus], a (H,) [negative],
    b/c (B,S,N); S % chunk == 0. Returns (y (B,S,H,P), state (B,H,P,N))."""
    bsz, s, h, p = x.shape
    n = b.shape[-1]
    assert s % chunk == 0
    nc = s // chunk
    return pl.pallas_call(
        partial(_ssd_kernel, nc=nc),
        grid=(bsz, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, h, p), lambda bi, ci: (bi, ci, 0, 0)),
            pl.BlockSpec((1, chunk, h), lambda bi, ci: (bi, ci, 0)),
            pl.BlockSpec((h,), lambda bi, ci: (0,)),
            pl.BlockSpec((1, chunk, n), lambda bi, ci: (bi, ci, 0)),
            pl.BlockSpec((1, chunk, n), lambda bi, ci: (bi, ci, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, h, p), lambda bi, ci: (bi, ci, 0, 0)),
            pl.BlockSpec((1, h, p, n), lambda bi, ci: (bi, 0, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, s, h, p), x.dtype),
            jax.ShapeDtypeStruct((bsz, h, p, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((h, p, n), jnp.float32)],
        interpret=interpret,
    )(x, dt, a, b, c)
