"""Public wrapper: pads S to the chunk size; padded tail uses dt=0 (decay
exp(0)=1, zero input) so y[:s] and the final state are exact."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..common import default_interpret, round_up
from .ref import ssd_ref
from .ssd import ssd_pallas


def ssd(x: jax.Array, dt: jax.Array, a: jax.Array, b: jax.Array, c: jax.Array,
        *, chunk: int = 128, interpret: bool | None = None):
    bsz, s, h, p = x.shape
    interpret = default_interpret() if interpret is None else interpret
    sp = round_up(s, chunk)
    pad = sp - s
    if pad:
        zx = ((0, 0), (0, pad), (0, 0), (0, 0))
        x = jnp.pad(x, zx)
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))  # dt=0 => identity step
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0)))
    y, state = ssd_pallas(x, dt, a, b, c, chunk=chunk, interpret=interpret)
    return y[:, :s], state


ssd_reference = ssd_ref
