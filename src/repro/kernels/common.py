"""Shared helpers for the Pallas TPU kernels.

All kernels target TPU (MXU-aligned tiles, VMEM BlockSpecs) and are
*validated* on CPU with ``interpret=True`` (the container has no TPU).
``default_interpret()`` picks the right mode automatically.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def pad_dim(x: jax.Array, axis: int, to: int) -> jax.Array:
    pad = to - x.shape[axis]
    if pad <= 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)
