"""Public wrapper for the fused RLS-score kernel: padding + diag plumbing.

Zero-padding is exact end to end: padded candidate rows produce garbage
scores that the caller masks; padded center columns are zeroed by the mask
inside the kernel before the quadform, and the padded block of W is the
identity (reg = 1 on invalid slots) so it contributes nothing.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ...families import diag_pre, get_family
from ..common import default_interpret, pad_dim, round_up
from .ref import rls_score_ref
from .rls_score import rls_score_pallas

#: Largest center buffer the fused kernel keeps resident in VMEM (W is
#: (M, M) fp32 -> 4 MB at 1024; beyond that the backend composes the
#: separate gram + quadform kernels instead).
MAX_FUSED_M = 1024


def rls_score(x_cand: jax.Array, z: jax.Array, w: jax.Array, zmask: jax.Array,
              lamn: jax.Array, sigma: float, *, kind: str = "gaussian",
              bn: int = 256, interpret: bool | None = None,
              bf16: bool = False) -> jax.Array:
    """Eq. 3 scores (K_ii - g_i^T W g_i) / (lam n) for each candidate row.

    x_cand (R, d), z (M, d) padded centers, w (M, M) the inverse of the
    regularized K_JJ, zmask (M,) center validity, lamn the scalar lam * n.
    Arbitrary R/M/d; pads internally to (bn, 128, 128). Returns (R,) fp32.
    """
    fam = get_family(kind)
    inv_scale = float(fam.inv_scale(sigma))
    n, d = x_cand.shape
    m = z.shape[0]
    interpret = default_interpret() if interpret is None else interpret
    kdiag = fam.epilogue(diag_pre(fam, x_cand), inv_scale).astype(jnp.float32)
    mpad = round_up(m, 128)
    xp = pad_dim(pad_dim(x_cand, 0, round_up(n, bn)), 1, round_up(d, 128))
    zp = pad_dim(pad_dim(z, 0, mpad), 1, round_up(d, 128))
    # padded W block = identity (matches the reg = 1 invalid-slot convention)
    wp = pad_dim(pad_dim(w, 0, mpad), 1, mpad)
    if mpad > m:
        eye_tail = (jnp.arange(mpad) >= m).astype(wp.dtype)
        wp = wp + jnp.diag(eye_tail)
    maskp = pad_dim(zmask.astype(jnp.float32), 0, mpad)
    kdp = pad_dim(kdiag, 0, round_up(n, bn))
    lamn2 = jnp.asarray(lamn, jnp.float32).reshape(1, 1)
    out = rls_score_pallas(xp, zp, wp, maskp, kdp, lamn2, inv_scale, kind=kind,
                           bn=bn, interpret=interpret, bf16=bf16)
    return out[:n]


rls_score_reference = rls_score_ref
