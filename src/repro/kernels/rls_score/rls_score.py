"""Pallas TPU kernel: fused Eq. 3 RLS score — gram tile -> quadform -> score.

The BLESS ladder's per-level hot loop evaluates

    l~(i) = (K_ii - k_i^T (K_JJ + lam n A)^{-1} k_i) / (lam n)

for a tile of candidates i against the full center set J. The pre-fusion
path moves the (R, M) Gram block through HBM three times (gram write,
G @ W read, elementwise read); this kernel keeps it in VMEM for its whole
lifetime: one MXU matmul forms the distance cross-term, the family epilogue
(VPU) produces the Gram tile, a second MXU matmul contracts it against the
resident (M, M) inverse W, and the score epilogue reduces to the (bn,)
output — one dispatch per ladder level.

Residency: z (M, d), W (M, M) and the center mask stay in VMEM across the
whole grid (M ~ d_eff, the same bound that lets FALKON replicate its
preconditioner), so the grid is 1-D over candidate tiles. ops.py guards the
M <= 1024 VMEM budget (4 MB for W at fp32) and the backend composes the
separate gram/quadform kernels above it.

The Cholesky-solve that produces W = (K_JJ + lam n A)^{-1} runs outside
(LAPACK/XLA beats a hand-rolled Pallas factorization at M ~ d_eff); what
the paper's cost model charges per level is the O(R M^2) contraction, which
is exactly what this kernel fuses. lam n arrives as a (1, 1) SMEM scalar so
sweeping the ladder's lam path reuses one compiled kernel.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ...families import get_family


def _rls_score_kernel(lamn_ref, x_ref, z_ref, w_ref, zmask_ref, kdiag_ref, o_ref,
                      *, kind: str, inv_scale: float, bf16: bool):
    fam = get_family(kind)  # static: resolved once per trace
    x = x_ref[...].astype(jnp.float32)  # (bn, d)
    z = z_ref[...].astype(jnp.float32)  # (M, d) — resident across the grid
    xc, zc = (x.astype(jnp.bfloat16), z.astype(jnp.bfloat16)) if bf16 else (x, z)
    prod = jax.lax.dot_general(xc, zc, (((1,), (1,)), ((), ())),
                               preferred_element_type=jnp.float32)  # (bn, M) MXU
    if fam.dot_only:
        pre = prod
    else:
        xn = jnp.sum(x * x, axis=-1)[:, None]
        zn = jnp.sum(z * z, axis=-1)[None, :]
        pre = jnp.maximum(xn + zn - 2.0 * prod, 0.0)
    # family epilogue on the VPU; invalid center columns zeroed so the padded
    # rows of W (identity there) cannot leak k(x, 0)^2 into the quadform
    g = fam.epilogue(pre, inv_scale) * zmask_ref[...][None, :]
    gw = g if not bf16 else g.astype(jnp.bfloat16)
    w = w_ref[...].astype(gw.dtype)  # (M, M) resident inverse
    acc = jax.lax.dot_general(gw, w, (((1,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)  # (bn, M) MXU
    quad = jnp.sum(acc * g, axis=1)  # (bn,)
    o_ref[...] = (kdiag_ref[...] - quad) / lamn_ref[0, 0]


@partial(jax.jit, static_argnames=("kind", "inv_scale", "bn", "interpret", "bf16"))
def rls_score_pallas(x: jax.Array, z: jax.Array, w: jax.Array, zmask: jax.Array,
                     kdiag: jax.Array, lamn: jax.Array, inv_scale: float, *,
                     kind: str = "gaussian", bn: int = 256,
                     interpret: bool = True, bf16: bool = False) -> jax.Array:
    """Fused Eq. 3 scores for pre-padded operands.

    x (R, d) candidates, z (M, d) centers, w (M, M) = (K_JJ + lam n A)^{-1},
    zmask (M,) center validity as fp32, kdiag (R,) = K_ii, lamn (1, 1) the
    scalar lam * n. Requires R % bn == 0, d % 128 == 0, M % 128 == 0.
    Returns (R,) fp32 scores (unclipped).
    """
    n, d = x.shape
    m = z.shape[0]
    assert n % bn == 0 and d % 128 == 0 and m % 128 == 0, (n, m, d)
    return pl.pallas_call(
        partial(_rls_score_kernel, kind=kind, inv_scale=float(inv_scale), bf16=bf16),
        grid=(n // bn,),
        in_specs=[
            pl.BlockSpec((1, 1), lambda i: (0, 0), memory_space=pltpu.SMEM),
            pl.BlockSpec((bn, d), lambda i: (i, 0)),  # candidate tile
            pl.BlockSpec((m, d), lambda i: (0, 0)),  # z: resident
            pl.BlockSpec((m, m), lambda i: (0, 0)),  # W: resident
            pl.BlockSpec((m,), lambda i: (0,)),  # center mask
            pl.BlockSpec((bn,), lambda i: (i,)),  # K_ii tile
        ],
        out_specs=pl.BlockSpec((bn,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=interpret,
    )(lamn, x, z, w, zmask, kdiag)
