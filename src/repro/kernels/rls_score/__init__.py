"""Fused Pallas RLS-score kernel (gram tile -> quadform -> Eq. 3 score)."""
from .ops import MAX_FUSED_M, rls_score, rls_score_reference
from .ref import masked_quadform_ref, rls_score_ref

__all__ = ["MAX_FUSED_M", "rls_score", "rls_score_reference",
           "masked_quadform_ref", "rls_score_ref"]
