"""Pre-fusion reference for the fused RLS-score path.

This is the Eq. 3 scorer exactly as the pre-fusion ladder computed it — a
masked Gram block, a jittered Cholesky of the padded ``K_JJ + lam n A``, a
triangular solve, and the ``(K_ii - q_i) / (lam n)`` epilogue as separate
ops. The ladder-level parity suite (tests/test_rls_score.py) holds every
fused backend path to this oracle across all registered kernel families.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _chol_jittered(a: jax.Array) -> jax.Array:
    """Eager double-jitter Cholesky (the pre-fusion _chol_with_jitter)."""
    eps = 1e-6 * jnp.mean(jnp.diagonal(a))
    chol = jnp.linalg.cholesky(a + eps * jnp.eye(a.shape[0], dtype=a.dtype))
    bad = jnp.any(jnp.isnan(chol))
    chol2 = jnp.linalg.cholesky(a + (1e3 * eps) * jnp.eye(a.shape[0], dtype=a.dtype))
    return jnp.where(bad, chol2, chol)


def masked_quadform_ref(kernel, x_cand: jax.Array, z: jax.Array, mask: jax.Array,
                        reg: jax.Array) -> jax.Array:
    """q_i = K_Ji^T (K_JJ ∘ mask + diag(reg))^{-1} K_Ji, via one trsm."""
    m = mask.astype(z.dtype)
    kjj = kernel.cross(z, z) * (m[:, None] * m[None, :]) + jnp.diag(reg)
    g = kernel.cross(x_cand, z) * m[None, :]
    chol = _chol_jittered(kjj)
    v = jax.scipy.linalg.solve_triangular(chol, g.T, lower=True)
    return jnp.sum(v * v, axis=0)


def rls_score_ref(kernel, x_cand: jax.Array, z: jax.Array, mask: jax.Array,
                  reg: jax.Array, lamn: jax.Array) -> jax.Array:
    """Eq. 3 scores  (K_ii - q_i) / (lam n)  — unclipped, unmasked.

    ``z`` (Mbuf, d) padded centers, ``mask`` (Mbuf,) validity, ``reg``
    (Mbuf,) the regularized diagonal (lam n A on valid slots, 1 on padding),
    ``lamn`` the scalar lam * n. Returns (Rbuf,) fp32.
    """
    kdiag = kernel.diag(x_cand)
    return (kdiag - masked_quadform_ref(kernel, x_cand, z, mask, reg)) / lamn
