"""Logical-axis -> mesh-axis sharding rules.

Model code annotates tensors with *logical* axes ("batch", "seq", "heads",
"ff", "experts", "vocab", "embed", ...). A MeshCtx maps those onto whatever
physical mesh is active:

  single pod   (data=16, model=16):        batch->data,  model dims->model
  multi pod    (pod=2, data=16, model=16): batch->(pod,data), model->model

Outside any mesh (CPU smoke tests) every annotation is a no-op, so the same
model code runs on 1 device and on 512.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# logical axis -> tuple of mesh axes (filtered by mesh at use time)
DEFAULT_RULES: dict[str, tuple[str, ...]] = {
    "batch": ("pod", "data"),
    "fsdp": ("data",),  # weight dim sharded FSDP-style (train only)
    "model": ("model",),
    "seq_shard": ("data",),  # long-context decode: KV sequence dim
    "seq_shard_wide": ("data", "model"),  # batch=1 long-context: all chips
    "none": (),
}


@dataclasses.dataclass
class MeshCtx:
    mesh: Optional[Mesh] = None
    rules: dict[str, tuple[str, ...]] = dataclasses.field(
        default_factory=lambda: dict(DEFAULT_RULES))
    fsdp: bool = True  # False at serve time: weights replicated over data

    def axes(self, logical: Optional[str]) -> Optional[tuple[str, ...]]:
        if logical is None or self.mesh is None:
            return None
        if logical == "fsdp" and not self.fsdp:
            return None
        ax = tuple(a for a in self.rules[logical] if a in self.mesh.axis_names)
        return ax or None


_TLS = threading.local()


def set_mesh_ctx(ctx: Optional[MeshCtx]) -> None:
    _TLS.ctx = ctx


def activate_mesh(mesh: Mesh):
    """Context manager making ``mesh`` the active physical mesh.

    ``jax.set_mesh`` where it exists; on older jax the ``Mesh`` object itself
    is the context manager. Both return a ctx usable as ``with
    activate_mesh(m):``.
    """
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh


def get_mesh_ctx() -> Optional[MeshCtx]:
    return getattr(_TLS, "ctx", None)


def logical_to_spec(*logical: Optional[str], ctx: Optional[MeshCtx] = None) -> P:
    """PartitionSpec from per-dimension logical names (None = replicated)."""
    ctx = ctx or get_mesh_ctx()
    if ctx is None or ctx.mesh is None:
        return P()
    return P(*(ctx.axes(l) for l in logical))


def shard(x: jax.Array, *logical: Optional[str]) -> jax.Array:
    """with_sharding_constraint by logical axes; no-op without a mesh ctx."""
    ctx = get_mesh_ctx()
    if ctx is None or ctx.mesh is None:
        return x
    assert x.ndim == len(logical), (x.shape, logical)
    spec = logical_to_spec(*logical, ctx=ctx)
    return jax.lax.with_sharding_constraint(x, NamedSharding(ctx.mesh, spec))


def named_sharding(*logical: Optional[str], ctx: Optional[MeshCtx] = None) -> Optional[NamedSharding]:
    ctx = ctx or get_mesh_ctx()
    if ctx is None or ctx.mesh is None:
        return None
    return NamedSharding(ctx.mesh, logical_to_spec(*logical, ctx=ctx))
