from .rules import (MeshCtx, activate_mesh, set_mesh_ctx, get_mesh_ctx, shard,
                    logical_to_spec)

__all__ = ["MeshCtx", "activate_mesh", "set_mesh_ctx", "get_mesh_ctx", "shard", "logical_to_spec"]
