from .rules import MeshCtx, set_mesh_ctx, get_mesh_ctx, shard, logical_to_spec

__all__ = ["MeshCtx", "set_mesh_ctx", "get_mesh_ctx", "shard", "logical_to_spec"]
