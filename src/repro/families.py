"""Kernel-family registry — one definition per family, shared by every path.

A ``KernelFamily`` owns the two pieces every execution path needs:

  * ``inv_scale``  — folds the bandwidth into the scalar the epilogue
    consumes (jnp-traceable for the jitted reference path; the Pallas
    wrappers call it with a concrete sigma and bake the float into the
    compiled kernel).
  * ``epilogue``   — the elementwise map from the MXU pre-activation to
    kernel values. For distance families the pre-activation is the clamped
    squared distance ``d2 >= 0``; for dot-product families (``dot_only``)
    it is the raw inner product ``x . z``. The same function body runs as
    the pure-jnp formula (``Kernel.cross``, the kernel refs) *and* as the
    VPU epilogue inside the Pallas tiles (``kernels/gram``,
    ``kernels/falkon_matvec``) — registering a family here makes it work
    on all three backends (jnp / Pallas / shard_map) at once.

This module is a deliberate leaf (imports nothing from ``repro``): it sits
below both ``repro.core`` and ``repro.kernels`` so neither import direction
creates a cycle. The public access points are re-exported from
``repro.core.gram`` and ``repro.api``.

Extension recipe (DESIGN.md §7): build a ``KernelFamily`` whose ``epilogue``
uses only elementwise jnp ops (VPU-safe inside a Pallas tile) and call
``register_kernel_family``. Nothing else needs editing — ``Kernel``, the
Pallas wrappers, and the shard_map path all resolve families by name.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class KernelFamily:
    """One kernel family k(x, z) = epilogue(pre, inv_scale(sigma)).

    Attributes:
      name: registry key ("gaussian", "matern32", ...).
      inv_scale: sigma -> the scalar folded into the epilogue. Must be plain
        arithmetic (traceable when sigma is a tracer on the jnp path).
      epilogue: (pre, inv_scale) -> kernel values, elementwise only (it runs
        on the VPU inside Pallas tiles). ``pre`` is the squared distance
        (clamped at 0) unless ``dot_only``, then the raw inner product.
      dot_only: family is a function of x . z (no distance epilogue); the
        Pallas kernels then skip the norm computation entirely.
      unit_diag: k(x, x) == 1 for all x (true for the distance families with
        epilogue(0) == 1; lets ``Kernel.diag`` return ones without compute).
    """

    name: str
    inv_scale: Callable[[jax.typing.ArrayLike], jax.typing.ArrayLike]
    epilogue: Callable[[Array, jax.typing.ArrayLike], Array]
    dot_only: bool = False
    unit_diag: bool = True


_FAMILY_REGISTRY: dict[str, KernelFamily] = {}


def register_kernel_family(family: KernelFamily, *, overwrite: bool = False) -> KernelFamily:
    """Register a family for resolution by name everywhere (jnp + Pallas +
    shard_map). Returns the family so definitions can be one expression."""
    if not overwrite and family.name in _FAMILY_REGISTRY:
        raise ValueError(f"kernel family {family.name!r} is already registered; "
                         "pass overwrite=True to replace it")
    _FAMILY_REGISTRY[family.name] = family
    return family


def kernel_family_names() -> list[str]:
    """Sorted names of every registered kernel family."""
    return sorted(_FAMILY_REGISTRY)


def get_family(name: str) -> KernelFamily:
    """Resolve a family by name; error messages enumerate the registry."""
    try:
        return _FAMILY_REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown kernel family {name!r}; registered: {kernel_family_names()}"
        ) from None


def diag_pre(family: KernelFamily, x: Array) -> Array:
    """Epilogue pre-activation for k(x_i, x_i): 0 for distance families,
    ``x . x`` for dot-product ones. One definition shared by ``Kernel.diag``
    and the fused RLS-score kernel wrapper, so every path that needs the
    Eq. 3 ``K_ii`` term agrees bit-for-bit on what the diagonal is."""
    if family.dot_only:
        return jnp.sum(x * x, axis=-1)
    return jnp.zeros((x.shape[0],), x.dtype)


# ---------------------------------------------------------------------------
# Built-in families. Epilogues are elementwise-only by contract; the +1e-30
# under the sqrt keeps the laplacian/matern gradient finite at d2 == 0 and is
# the single formula both the jnp reference and the Pallas tiles use (so
# cross-backend parity is exact up to fp reassociation).
# ---------------------------------------------------------------------------

GAUSSIAN = register_kernel_family(KernelFamily(
    name="gaussian",
    inv_scale=lambda sigma: 1.0 / (2.0 * sigma**2),
    epilogue=lambda d2, s: jnp.exp(-d2 * s),
))

LAPLACIAN = register_kernel_family(KernelFamily(
    name="laplacian",
    inv_scale=lambda sigma: 1.0 / sigma,
    epilogue=lambda d2, s: jnp.exp(-jnp.sqrt(d2 + 1e-30) * s),
))

LINEAR = register_kernel_family(KernelFamily(
    name="linear",
    inv_scale=lambda sigma: 1.0,  # bandwidth-free
    epilogue=lambda prod, s: prod,
    dot_only=True,
    unit_diag=False,
))

#: Matern-3/2: (1 + r) e^{-r} with r = sqrt(3) ||x-z|| / sigma — the once-
#: differentiable middle ground between laplacian (nu=1/2) and gaussian.
#: NOTE inv_scale stays pure-Python arithmetic (no jnp): it must yield a
#: Python float for concrete sigma even when *called from inside a trace*
#: (the Pallas wrappers bake float(inv_scale(sigma)) into the kernel).
MATERN32 = register_kernel_family(KernelFamily(
    name="matern32",
    inv_scale=lambda sigma: 3.0**0.5 / sigma,
    epilogue=lambda d2, s: (lambda r: (1.0 + r) * jnp.exp(-r))(jnp.sqrt(d2 + 1e-30) * s),
))

#: Cauchy (rational-quadratic, alpha=1): 1 / (1 + ||x-z||^2 / sigma^2) —
#: heavy-tailed, no exp on the hot path.
CAUCHY = register_kernel_family(KernelFamily(
    name="cauchy",
    inv_scale=lambda sigma: 1.0 / sigma**2,
    epilogue=lambda d2, s: 1.0 / (1.0 + d2 * s),
))
