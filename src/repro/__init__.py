"""repro — BLESS / FALKON-BLESS (NeurIPS 2018) as a production JAX framework.

Layers: api (the public front door: Sampler/Estimator objects + kernel-family
registry), core (the paper), kernels (Pallas TPU hot-spots), models+configs
(assigned architecture zoo), data/optim/training/serving/checkpoint/runtime
(substrates), sharding+launch (512-chip SPMD distribution + dry-run).
"""
__version__ = "1.0.0"
