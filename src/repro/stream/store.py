"""Host-resident chunked data plane for the out-of-core streaming backend.

``ChunkStore`` keeps X (and optionally y) in **host** memory as C-contiguous
fp32 row chunks and hands them to the device one chunk at a time. Nothing
here assumes X fits in device memory — the device only ever sees a bounded
working set:

  * at most two X chunks (the one being contracted + the prefetched next),
  * one transient (chunk, M) Gram tile per in-flight contraction,
  * the O(M)/(M, k) accumulators and the O(n) prediction output.

``device_chunks`` is the double-buffered copy loop: the host->device copy of
chunk i+1 is *issued* (``jax.device_put`` is asynchronous) before chunk i is
yielded to the consumer, so under JAX's async dispatch the copy of the next
chunk overlaps the contraction of the current one — the same schedule as a
software-pipelined DMA loop.

Every device allocation this subsystem makes is metered by a module-level
byte tracker (``peak_device_bytes``): CPU devices expose no
``memory_stats()``, so the subsystem carries its own honest accounting of
what it put on device, and the bigk benchmark / tests read the peak from
here (plus ``Device.memory_stats()`` where the platform provides it).
"""
from __future__ import annotations

import dataclasses
import threading

import jax
import numpy as np

Array = jax.Array

#: Default rows per device chunk by platform. Sized so a (chunk, M) Gram
#: tile at M ~ 1k stays ~64 MB (CPU cache/TLB friendly) / fits HBM working
#: sets comfortably with double buffering on accelerators.
STREAM_CHUNK = {"cpu": 16384, "gpu": 65536, "tpu": 65536}


def default_chunk() -> int:
    """Platform default rows-per-chunk (see ``STREAM_CHUNK``)."""
    return STREAM_CHUNK.get(jax.default_backend(), 16384)


# ---------------------------------------------------------------------------
# Device-byte accounting
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class _DeviceBytes:
    """Bytes the stream subsystem currently has resident on device, plus the
    high-water mark. Guarded by a lock — the serving engines may stream from
    several host threads."""

    current: int = 0
    peak: int = 0
    lock: threading.Lock = dataclasses.field(default_factory=threading.Lock)

    def add(self, nbytes: int) -> None:
        with self.lock:
            self.current += nbytes
            self.peak = max(self.peak, self.current)

    def sub(self, nbytes: int) -> None:
        with self.lock:
            self.current = max(0, self.current - nbytes)

    def note_transient(self, nbytes: int) -> None:
        """Record a short-lived allocation (a Gram tile inside a compiled
        chunk step) that never outlives one loop iteration: bumps the peak
        without moving ``current``."""
        with self.lock:
            self.peak = max(self.peak, self.current + nbytes)


_TRACKER = _DeviceBytes()


def reset_peak_device_bytes() -> None:
    """Zero the subsystem's device-byte high-water mark (benchmark prologue)."""
    with _TRACKER.lock:
        _TRACKER.current = 0
        _TRACKER.peak = 0


def peak_device_bytes() -> int:
    """High-water mark of device bytes the stream subsystem allocated
    (chunks in flight + transient Gram tiles + accumulators) since the last
    ``reset_peak_device_bytes``. This is the subsystem's own meter — on
    platforms with ``Device.memory_stats()`` compare against
    ``device_memory_stats()`` for the allocator's view."""
    return _TRACKER.peak


def device_memory_stats() -> dict | None:
    """The default device's allocator stats (``peak_bytes_in_use`` etc.),
    or None where the platform does not expose them (CPU)."""
    dev = jax.local_devices()[0]
    stats = getattr(dev, "memory_stats", None)
    return stats() if stats is not None else None


def _nbytes(a) -> int:
    return int(np.prod(a.shape)) * a.dtype.itemsize if hasattr(a, "shape") else 0


# ---------------------------------------------------------------------------
# ChunkStore
# ---------------------------------------------------------------------------


class ChunkStore:
    """Host-resident (X, y) exposed as fixed-size row chunks.

    Array-like enough to flow through the existing seams unchanged: it has
    ``shape``/``ndim``/``dtype``/``len`` and concrete-index ``__getitem__``
    (row gather -> small device array, which is how ``falkon_fit`` and the
    samplers pull center coordinates out of it). It is **not** a jax type —
    anything that would trace it (jit operands, ``lax.cond`` branches)
    raises a ``TypeError`` pointing at the streaming entry points instead of
    silently materializing.

    Attributes:
      x: the host (n, d) fp32 array (C-contiguous; row slices are contiguous
        so each host->device copy is one memcpy — the portable stand-in for
        pinned staging buffers).
      y: optional aligned (n,) or (n, k) fp32 targets, chunked in lockstep.
      chunk: rows per chunk (the last chunk carries the remainder).
    """

    def __init__(self, x, y=None, *, chunk: int | None = None):
        xb = np.ascontiguousarray(np.asarray(x, dtype=np.float32))
        if xb.ndim != 2:
            raise ValueError(f"ChunkStore x must be (n, d), got {xb.shape}")
        self._n = xb.shape[0]
        self._xbuf = xb
        self._ybuf = None
        if y is not None:
            yb = np.ascontiguousarray(np.asarray(y, dtype=np.float32))
            if yb.shape[0] != self._n:
                raise ValueError(
                    f"y rows {yb.shape[0]} != x rows {self._n}")
            self._ybuf = yb
        self.chunk = max(1, int(chunk) if chunk is not None else default_chunk())

    def append(self, x_new, y_new=None) -> int:
        """Append rows to the store (amortized O(1): capacity-doubling host
        buffers, so the online ingest path never re-copies the history per
        batch). Returns the new row count.

        Existing rows never move or change value — ``x``/``y`` are views of
        a prefix that only grows, which is what lets a background center
        refresh read a row-count snapshot while appends continue.
        """
        xb = np.ascontiguousarray(np.asarray(x_new, dtype=np.float32))
        if xb.ndim != 2 or xb.shape[1] != self._xbuf.shape[1]:
            raise ValueError(f"append rows must be (r, {self._xbuf.shape[1]}), "
                             f"got {xb.shape}")
        yb = None
        if self._ybuf is not None:
            if y_new is None:
                raise ValueError("this store carries y; append needs y_new")
            yb = np.ascontiguousarray(np.asarray(y_new, dtype=np.float32))
            if yb.shape[0] != xb.shape[0] or yb.shape[1:] != self._ybuf.shape[1:]:
                raise ValueError(
                    f"y_new shape {yb.shape} does not match {xb.shape[0]} "
                    f"rows of {self._ybuf.shape[1:]} targets")
        elif y_new is not None:
            raise ValueError("this store has no y; cannot append y_new")
        need = self._n + xb.shape[0]
        if need > self._xbuf.shape[0]:
            cap = max(need, 2 * self._xbuf.shape[0])
            grown = np.empty((cap,) + self._xbuf.shape[1:], np.float32)
            grown[: self._n] = self._xbuf[: self._n]
            self._xbuf = grown
            if self._ybuf is not None:
                growny = np.empty((cap,) + self._ybuf.shape[1:], np.float32)
                growny[: self._n] = self._ybuf[: self._n]
                self._ybuf = growny
        self._xbuf[self._n:need] = xb
        if yb is not None:
            self._ybuf[self._n:need] = yb
        self._n = need
        return self._n

    # -- array-like surface --------------------------------------------------

    @property
    def x(self) -> np.ndarray:
        """The host (n, d) fp32 X — a contiguous view of the growth buffer."""
        return self._xbuf[: self._n]

    @property
    def y(self) -> np.ndarray | None:
        """The host (n,) / (n, k) fp32 targets (None when not stored)."""
        return None if self._ybuf is None else self._ybuf[: self._n]

    @property
    def shape(self) -> tuple[int, int]:
        """(n, d) of the stored X."""
        return (self._n, self._xbuf.shape[1])

    @property
    def ndim(self) -> int:
        """Always 2 (rows x features)."""
        return 2

    @property
    def dtype(self):
        """fp32 — the storage and transfer dtype."""
        return self.x.dtype

    def __len__(self) -> int:
        return self.x.shape[0]

    @property
    def n_chunks(self) -> int:
        """Number of row chunks (ceil(n / chunk))."""
        return -(-self.x.shape[0] // self.chunk)

    def chunk_slices(self) -> list[slice]:
        """The row slice of every chunk, in streaming order."""
        n, c = self.x.shape[0], self.chunk
        return [slice(i, min(i + c, n)) for i in range(0, n, c)]

    def __getitem__(self, idx) -> Array:
        """Concrete row gather -> device array (centers, candidate sets).

        Accepts an int, a slice, or an integer index array. Tracers are
        rejected: a traced gather would force the whole host array onto the
        device, which is exactly what this store exists to avoid.
        """
        if isinstance(idx, jax.core.Tracer):
            raise TypeError(
                "ChunkStore rows can only be gathered with concrete indices "
                "(a traced gather would materialize host data on device); "
                "stream through StreamBackend, or gather before tracing")
        if isinstance(idx, (int, np.integer, slice)):
            return jax.numpy.asarray(self.x[idx])
        return jax.numpy.asarray(self.x[np.asarray(idx)])

    def to_device(self) -> Array:
        """The whole X as one device array — O(n d), NOT O(n M); for code
        paths (oracles, tiny problems) that genuinely want X device-resident."""
        return jax.numpy.asarray(self.x)

    def __array__(self, dtype=None, copy=None):
        """numpy protocol: the host X itself. Lets ``jnp.asarray(store)``
        work as an explicit O(n d) escape hatch (the data, never (n, M)) for
        the oracle/direct-solver paths that cannot stream."""
        return self.x if dtype is None else self.x.astype(dtype)


# ---------------------------------------------------------------------------
# Double-buffered chunk iteration
# ---------------------------------------------------------------------------


def _sources(x, aux):
    """Normalize (x, aux) into (host-or-device row source, aligned aux list,
    chunk row count). ``x`` may be a ChunkStore, numpy array, or jax array."""
    if isinstance(x, ChunkStore):
        return x.x, aux, x.chunk
    return x, aux, None


def device_chunks(x, aux=None, *, chunk: int | None = None):
    """Yield ``(xb, auxb)`` device chunks of ``x`` (and the aligned optional
    ``aux`` rows — targets, masks) with one-chunk prefetch.

    Host sources (ChunkStore / numpy) are copied chunk-by-chunk with
    ``jax.device_put``; the put for chunk i+1 is issued before chunk i is
    yielded, so the copy overlaps the consumer's compute under async
    dispatch, and the tracker never sees more than two chunks resident.
    Device-resident ``x`` (small-n parity paths) is sliced lazily with no
    copies and no accounting.
    """
    src, aux, store_chunk = _sources(x, aux)
    c = int(chunk) if chunk is not None else (store_chunk or default_chunk())
    c = max(1, c)
    n = src.shape[0]
    slices = [slice(i, min(i + c, n)) for i in range(0, n, c)]
    host = isinstance(src, np.ndarray)

    def put(sl):
        xb = src[sl]
        ab = None if aux is None else aux[sl]
        if host:
            xb = jax.device_put(xb)
            _TRACKER.add(_nbytes(xb))
            if ab is not None and isinstance(ab, np.ndarray):
                ab = jax.device_put(ab)
                _TRACKER.add(_nbytes(ab))
        return xb, ab

    def drop(pair):
        if host:
            xb, ab = pair
            _TRACKER.sub(_nbytes(xb))
            if ab is not None:
                _TRACKER.sub(_nbytes(ab))

    cur = put(slices[0])
    for sl in slices[1:]:
        nxt = put(sl)  # async H2D for chunk i+1 issued before chunk i runs
        yield cur
        drop(cur)
        cur = nxt
    yield cur
    drop(cur)
