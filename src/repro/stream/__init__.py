"""``repro.stream`` — the out-of-core streaming subsystem (DESIGN.md §10).

``ChunkStore`` keeps (X, y) host-resident in fixed-size row chunks;
``StreamBackend`` serves the full kernel-operator ``Backend`` protocol by
double-buffered chunk streaming (copy chunk i+1 while contracting chunk i),
so FALKON, the BLESS/Chen-Yang samplers, predict and the estimators run at
n far beyond device memory without code changes — no (n, M) array is ever
materialized. Registered as ``"stream"`` (``REPRO_BACKEND=stream``;
``"stream:pallas"`` composes the per-tile contraction with another backend).
"""
from .backend import MATERIALIZE_ELEMS, StreamBackend
from .store import (
    STREAM_CHUNK,
    ChunkStore,
    default_chunk,
    device_chunks,
    device_memory_stats,
    peak_device_bytes,
    reset_peak_device_bytes,
)

__all__ = [
    "ChunkStore",
    "StreamBackend",
    "STREAM_CHUNK",
    "MATERIALIZE_ELEMS",
    "default_chunk",
    "device_chunks",
    "device_memory_stats",
    "peak_device_bytes",
    "reset_peak_device_bytes",
]
