"""``StreamBackend`` — the full ``Backend`` protocol over chunked host data.

Every K_nM-shaped contraction is served as a double-buffered loop over the
``repro.stream.store`` chunk iterator: while chunk i's (chunk, M) Gram tile
is built and contracted on device, the host->device copy of chunk i+1 is
already in flight. The tile is consumed immediately — reduced into the
(M,)/(M, k) accumulator (``knm_quadratic`` / ``knm_t``), the (R,) score
vector (``masked_quadform`` / ``rls_scores``), or the (n,)/(n, k) prediction
(``knm_matvec``) — so no (n, M) array ever exists, the same tiling argument
as memory-efficient attention. ``gram_block`` is the one protocol method
whose *output* is (n, m); it carries an explicit element-count guard and
raises past it rather than silently materializing.

Composition: the per-tile contraction is delegated to an ``inner`` backend
(``inner.gram_block`` builds each tile), so ``StreamBackend(inner=
PallasBackend())`` runs the fused TPU kernels per tile and
``StreamBackend(inner=ShardedBackend())`` shard_maps each tile over the
local mesh — out-of-core capacity composed with single-tile speed. The
registry spells this ``"stream:pallas"`` (see ``resolve_backend``).

Accumulation order is the chunk order (row order), fixed and deterministic:
repeated calls on the same data produce bit-identical results. The sum is
associated differently from the jnp streamer's lax.scan (2048-row blocks vs
``chunk``-row chunks), so cross-backend agreement is the documented 1e-4
scale-relative parity, not bit-equality.

``jit_safe`` is False — the loop needs the host — so fits through this
backend take ``falkon_fit``'s host CG path and the BLESS ladder runs its
eager phases, both of which already accept array-likes like ``ChunkStore``.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, ClassVar

import jax
import jax.numpy as jnp
import numpy as np

from ..core.backend import Backend, JnpBackend, _quadform_from_chol
from ..core.gram import Kernel
from ..core.leverage import _chol_with_jitter
from .store import _TRACKER, device_chunks

Array = jax.Array

#: ``gram_block`` materialization guard: refuse outputs above this many fp32
#: elements (default 2^26 = 256 MB). Small-problem callers (K_MM, ladder
#: levels, parity tests) pass untouched; an accidental (n, M) materialization
#: at out-of-core n raises instead of silently defeating the subsystem.
MATERIALIZE_ELEMS = 1 << 26


# ---------------------------------------------------------------------------
# Per-chunk contraction steps.
#
# One function per seam method; each builds its (chunk, M) Gram tile through
# the *inner* backend and reduces it on the spot. The jit-wrapped variants
# (inner static — backends are frozen hashable dataclasses) are used when the
# inner backend is jit-safe: the whole tile-build + reduce is then one
# compiled call per chunk, and the uniform-chunk + single-tail layout keeps
# the cache at <= 2 executables per (shapes, inner). Non-jit-safe inners
# (Pallas, shard_map) run the same bodies eagerly — their dispatch needs
# concrete tile parameters.
# ---------------------------------------------------------------------------


def _quad_chunk(kernel, xb, z, v, acc, *, inner):
    g = inner.gram_block(kernel, xb, z)
    return acc + g.T @ (g @ v)


def _quad_chunk_masked(kernel, xb, z, v, mb, acc, *, inner):
    g = inner.gram_block(kernel, xb, z)
    t = g @ v
    t = t * (mb if t.ndim == mb.ndim else mb[:, None])
    return acc + g.T @ t


def _knmt_chunk(kernel, xb, z, yb, acc, *, inner):
    return acc + inner.gram_block(kernel, xb, z).T @ yb


def _matvec_chunk(kernel, xb, z, v, *, inner):
    return inner.gram_block(kernel, xb, z) @ v


def _quadform_chunk(kernel, xb, z, maskf, chol, *, inner):
    g = inner.gram_block(kernel, xb, z) * maskf[None, :]
    return _quadform_from_chol(chol, g)


def _rls_chunk(kernel, xb, z, maskf, chol, lamn, *, inner):
    g = inner.gram_block(kernel, xb, z) * maskf[None, :]
    return (kernel.diag(xb) - _quadform_from_chol(chol, g)) / lamn


_jit = partial(jax.jit, static_argnames=("inner",))
_quad_chunk_jit = _jit(_quad_chunk)
_quad_chunk_masked_jit = _jit(_quad_chunk_masked)
_knmt_chunk_jit = _jit(_knmt_chunk)
_matvec_chunk_jit = _jit(_matvec_chunk)
_quadform_chunk_jit = _jit(_quadform_chunk)
_rls_chunk_jit = _jit(_rls_chunk)


@dataclasses.dataclass(frozen=True)
class StreamBackend(Backend):
    """Out-of-core streaming backend (see module docstring).

    Attributes:
      inner: the backend that builds each (chunk, M) Gram tile; jnp by
        default, Pallas / shard_map via ``"stream:pallas"`` etc.
      chunk: rows per device chunk; None defers to the ``ChunkStore``'s own
        chunk size (or the platform default for device-resident inputs).
      materialize_elems: the ``gram_block`` output-size guard (elements).
    """

    name: ClassVar[str] = "stream"
    jit_safe: ClassVar[bool] = False
    inner: Backend = dataclasses.field(default_factory=JnpBackend)
    chunk: int | None = None
    materialize_elems: int = MATERIALIZE_ELEMS

    def with_inner(self, inner: Backend) -> "StreamBackend":
        """This wrapper with its per-tile backend swapped — the composition
        hook ``resolve_backend`` uses for ``"stream:<inner>"`` specs."""
        return dataclasses.replace(self, inner=inner)

    def _pick(self, eager: Callable, jitted: Callable) -> Callable:
        return jitted if self.inner.jit_safe else eager

    def _note_tile(self, rows: int, m: int) -> None:
        _TRACKER.note_transient(4 * rows * m)

    # -- protocol -----------------------------------------------------------

    def gram_block(self, kernel: Kernel, x: Array, z: Array) -> Array:
        """K(X, Z) (n, m) fp32, streamed chunk-by-chunk through the inner
        backend — guarded: raises if the *output* exceeds
        ``materialize_elems`` (this method's result is the one (n, m)
        array the protocol cannot avoid)."""
        n, m = x.shape[0], z.shape[0]
        if n * m > self.materialize_elems:
            raise ValueError(
                f"stream backend refuses to materialize a ({n}, {m}) Gram "
                f"block ({n * m} > materialize_elems={self.materialize_elems}"
                "); out-of-core problems must go through the knm_* / "
                "quadform operators, which never build (n, M) — or raise "
                "StreamBackend(materialize_elems=...) if this block is "
                "genuinely meant to exist")
        blocks = []
        for xb, _ in device_chunks(x, chunk=self.chunk):
            self._note_tile(xb.shape[0], m)
            blocks.append(self.inner.gram_block(kernel, xb, z))
        return blocks[0] if len(blocks) == 1 else jnp.concatenate(blocks, axis=0)

    def masked_quadform(self, kernel: Kernel, x_cand: Array, z: Array,
                        mask: Array, reg: Array) -> Array:
        """Eq. 3 quadratic form: factor the (Mbuf, Mbuf) K_JJ once, then
        stream candidate chunks through one trsm/GEMM solve each."""
        maskf = mask.astype(z.dtype)
        kjj = (self.inner.gram_block(kernel, z, z)
               * (maskf[:, None] * maskf[None, :]) + jnp.diag(reg))
        chol = _chol_with_jitter(kjj)
        step = self._pick(_quadform_chunk, _quadform_chunk_jit)
        outs = []
        for xb, _ in device_chunks(x_cand, chunk=self.chunk):
            self._note_tile(xb.shape[0], z.shape[0])
            outs.append(step(kernel, xb, z, maskf, chol, inner=self.inner))
        return outs[0] if len(outs) == 1 else jnp.concatenate(outs)

    def rls_scores(self, kernel: Kernel, x_cand: Array, z: Array,
                   z_mask: Array, reg: Array, lamn: Array) -> Array:
        """Eq. 3 scores with the K_JJ factorization hoisted out of the chunk
        loop (the inner backend's own fused scorer refactors it per call,
        which would repeat the (Mbuf, Mbuf) Cholesky once per chunk)."""
        maskf = z_mask.astype(x_cand.dtype if hasattr(x_cand, "dtype") else jnp.float32)
        kjj = (self.inner.gram_block(kernel, z, z)
               * (maskf[:, None] * maskf[None, :]) + jnp.diag(reg))
        chol = _chol_with_jitter(kjj)
        step = self._pick(_rls_chunk, _rls_chunk_jit)
        outs = []
        for xb, _ in device_chunks(x_cand, chunk=self.chunk):
            self._note_tile(xb.shape[0], z.shape[0])
            outs.append(step(kernel, xb, z, maskf, chol, lamn, inner=self.inner))
        return outs[0] if len(outs) == 1 else jnp.concatenate(outs)

    def knm_quadratic(self, kernel: Kernel, x: Array, z: Array, *,
                      mask: Array | None = None):
        """CG quadratic op v -> K_nM^T (K_nM v): every call re-streams X
        from host with double-buffered copies, folding each (chunk, M) tile
        into the (M,)/(M, k) accumulator in chunk order. An optional
        ``mask`` ((n,) or (n, k) per-column row exclusion — exact CV) rides
        the same chunk iterator as the aux stream, so masked ops stay
        out-of-core: only (chunk, k) mask slices ever reach the device."""
        m = z.shape[0]
        if mask is None:
            step = self._pick(_quad_chunk, _quad_chunk_jit)

            def op(v: Array) -> Array:
                acc = jnp.zeros((m,) + v.shape[1:], jnp.float32)
                for xb, _ in device_chunks(x, chunk=self.chunk):
                    self._note_tile(xb.shape[0], m)
                    acc = step(kernel, xb, z, v, acc, inner=self.inner)
                return acc

            return op

        mstep = self._pick(_quad_chunk_masked, _quad_chunk_masked_jit)

        def masked_op(v: Array) -> Array:
            acc = jnp.zeros((m,) + v.shape[1:], jnp.float32)
            for xb, mb in device_chunks(x, aux=mask, chunk=self.chunk):
                self._note_tile(xb.shape[0], m)
                acc = mstep(kernel, xb, z, v, mb, acc, inner=self.inner)
            return acc

        return masked_op

    def knm_t(self, kernel: Kernel, x: Array, z: Array, y: Array, *,
              mask: Array | None = None) -> Array:
        """K_nM^T y with y chunked in lockstep with X; (n,) -> (M,) or an
        (n, k) panel -> (M, k), one tile serving every column. ``mask``
        folds into the targets (K_nM^T (mask * y)) before chunking."""
        if mask is not None:
            if isinstance(y, jax.Array):
                y = y * jnp.asarray(mask, y.dtype)
            else:  # host-resident targets stay on host (out-of-core n)
                y = np.asarray(y) * np.asarray(mask)
        m = z.shape[0]
        step = self._pick(_knmt_chunk, _knmt_chunk_jit)
        acc = jnp.zeros((m,) + y.shape[1:], jnp.float32)
        for xb, yb in device_chunks(x, aux=y, chunk=self.chunk):
            self._note_tile(xb.shape[0], m)
            acc = step(kernel, xb, z, yb, acc, inner=self.inner)
        return acc

    def knm_matvec(self, kernel: Kernel, x: Array, z: Array, v: Array) -> Array:
        """K(X, Z) v — predict: per-chunk outputs concatenated to (n,) or
        (n, k); the output is the only O(n) device array this path makes."""
        outs = []
        for xb, _ in device_chunks(x, chunk=self.chunk):
            self._note_tile(xb.shape[0], z.shape[0])
            outs.append(self._pick(_matvec_chunk, _matvec_chunk_jit)(
                kernel, xb, z, v, inner=self.inner))
        return outs[0] if len(outs) == 1 else jnp.concatenate(outs, axis=0)
