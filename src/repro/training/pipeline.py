"""GPipe-style pipeline parallelism over a ``pipe`` mesh axis.

shard_map + lax.ppermute: layer groups are split into S stages (stage s
holds its own slice of the stacked layer params); microbatches stream
through the classic GPipe schedule — at step t, stage s computes microbatch
(t - s). Differentiation works through the schedule automatically: the
transpose of ppermute is the reverse permute, so jax.grad of the pipelined
forward *is* the GPipe backward (bubble included).

This is the 1000+-node scaling dimension the 2D (data x model) mesh lacks:
at fixed global batch, pipe stages multiply the reachable chip count
without widening TP. ``make_pipeline_mesh()`` (4 x 8 x 16 = 512) +
tests/test_pipeline.py prove the lowering; examples stay 2D because every
assigned arch fits the 2D mesh (DESIGN.md §5).
"""
from __future__ import annotations

from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

Array = jax.Array


def _axis_size(axis: str) -> int:
    """jax.lax.axis_size where it exists; the axis-env lookup on older jax
    (where ``axis_frame`` returns the size directly)."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis)
    size = jax.core.axis_frame(axis)
    return size if isinstance(size, int) else size.size


def _shift_right(x: Array, axis: str) -> Array:
    n = _axis_size(axis)
    return jax.lax.ppermute(x, axis, [(i, (i + 1) % n) for i in range(n)])


def pipeline_apply(stage_fn: Callable[[Any, Array], Array], n_stages: int,
                   n_microbatches: int, mesh: Mesh, *, axis: str = "pipe",
                   extra_specs: P = P()) -> Callable[[Any, Array], Array]:
    """Build a pipelined forward.

    stage_fn(stage_params, x_mb) -> x_mb : one stage's computation on one
      microbatch (a slice of the layer stack, scanned internally).
    params: pytree with leading dim n_stages on every leaf (stage-stacked).
    x: (n_microbatches, mb, ...) microbatched input.
    Returns (n_microbatches, mb, ...) outputs (as produced by the last
    stage, gathered back to all pipe shards for the loss).
    """
    steps = n_stages + n_microbatches - 1

    def pipelined(params: Any, x: Array) -> Array:
        s_idx = jax.lax.axis_index(axis)
        mb_shape = x.shape[1:]

        def body(carry, t):
            state, outputs = carry
            # stage 0 injects microbatch t; everyone else takes the
            # neighbour's activation from the previous step
            inject = x[jnp.minimum(t, n_microbatches - 1)]
            state = jnp.where(s_idx == 0, inject, state)
            state = stage_fn(params, state)
            # last stage's finished microbatch lands in the output buffer
            out_t = t - (n_stages - 1)
            write = (s_idx == n_stages - 1) & (out_t >= 0)
            outputs = jax.lax.dynamic_update_index_in_dim(
                outputs,
                jnp.where(write, state, jax.lax.dynamic_index_in_dim(
                    outputs, jnp.maximum(out_t, 0), keepdims=False)),
                jnp.maximum(out_t, 0), axis=0)
            # hand activations to the next stage
            state = _shift_right(state, axis)
            return (state, outputs), None

        init = (jnp.zeros(mb_shape, x.dtype),
                jnp.zeros((n_microbatches,) + mb_shape, x.dtype))
        (_, outputs), _ = jax.lax.scan(body, init, jnp.arange(steps))
        # outputs are populated only on the last stage: broadcast them to
        # every pipe shard so the (replicated-over-pipe) loss sees them
        outputs = jax.lax.psum(
            jnp.where(s_idx == n_stages - 1, outputs, jnp.zeros_like(outputs)), axis)
        return outputs

    def run(params: Any, x: Array) -> Array:
        return shard_map(pipelined, mesh=mesh,
                         in_specs=(P(axis), P()), out_specs=P(),
                         check_rep=False)(params, x)

    return run


def stack_stages(params_layers: Any, n_stages: int) -> Any:
    """Reshape leading layer dim L -> (n_stages, L/n_stages) on every leaf."""

    def re(p):
        l = p.shape[0]
        assert l % n_stages == 0, (l, n_stages)
        return p.reshape((n_stages, l // n_stages) + p.shape[1:])

    return jax.tree.map(re, params_layers)
