from .train import TrainState, make_train_step, train_state_init

__all__ = ["TrainState", "make_train_step", "train_state_init"]
