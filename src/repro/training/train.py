"""Training step: grads (with optional microbatch accumulation) + AdamW.

``make_train_step`` returns the pure function the launcher jits (dry-run
AOT-lowers the same function). Microbatching splits the batch on the host-
visible leading axis and accumulates grads in fp32 via lax.scan — the
standard way to trade activation memory for steps; remat already bounds
per-layer activations (model.py).
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from ..models import loss_fn
from ..models.config import ArchConfig
from ..optim import OptConfig, adamw_init, adamw_update


class TrainState(NamedTuple):
    params: Any
    opt: dict


def train_state_init(cfg: ArchConfig, key) -> TrainState:
    from ..models import init_params

    params = init_params(cfg, key)
    return TrainState(params=params, opt=adamw_init(params))


def make_train_step(cfg: ArchConfig, opt_cfg: OptConfig, *, microbatches: int = 1,
                    loss_chunks: int = 8, grad_shardings: Any = None) -> Callable:
    """grad_shardings: optional NamedSharding pytree (usually the optimizer
    state's fsdp+tp specs) pinned onto the fp32 grad accumulator — without
    it the accumulator inherits the params' sharding, which under ZeRO-1 is
    TP-only and costs a data-replicated fp32 copy of the model."""

    def loss_wrapped(params, batch):
        return loss_fn(params, cfg, batch, n_chunks=loss_chunks)

    def train_step(state: TrainState, batch: dict) -> tuple[TrainState, dict]:
        if microbatches == 1:
            loss, grads = jax.value_and_grad(loss_wrapped)(state.params, batch)
        else:
            def split(x):
                return x.reshape((microbatches, x.shape[0] // microbatches) + x.shape[1:])

            mb = jax.tree.map(split, batch)

            def pin(g):
                if grad_shardings is None:
                    return g
                return jax.tree.map(jax.lax.with_sharding_constraint, g, grad_shardings)

            def acc_fn(carry, mbatch):
                l, g = jax.value_and_grad(loss_wrapped)(state.params, mbatch)
                return (carry[0] + l,
                        pin(jax.tree.map(lambda a, b: a + b.astype(jnp.float32),
                                         carry[1], g))), None

            zero = (jnp.zeros(()), pin(jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params)))
            (loss_sum, gsum), _ = jax.lax.scan(acc_fn, zero, mb)
            loss = loss_sum / microbatches
            grads = jax.tree.map(lambda g: g / microbatches, gsum)
        params, opt = adamw_update(state.params, grads, state.opt, opt_cfg)
        metrics = {"loss": loss, "lr": opt_cfg.lr(opt["step"]),
                   "grad_norm": _gnorm(grads)}
        return TrainState(params, opt), metrics

    return train_step


def _gnorm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))
