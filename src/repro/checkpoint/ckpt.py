"""Sharded, manifest-based checkpointing with async writes and cross-mesh
(elastic) restore.

Layout:  <dir>/step_<N>/manifest.json + one .npy per leaf (keyed by the
flattened tree path). Writes go to a temp dir and are renamed atomically;
``latest_step`` only ever sees complete checkpoints — a mid-write failure
loses at most one checkpoint, never corrupts one (the restart guarantee).

Restore is *mesh-free*: leaves come back as host numpy and are device_put
with whatever sharding the (possibly different-sized) new mesh prescribes —
that is the elastic-rescale path. On a multi-host pod each process would
write only its addressable shards (the manifest records per-leaf global
shapes already); single-process CPU writes everything.

Crash windows are first-class: every filesystem step of ``save_checkpoint``
hosts a ``ckpt.torn_write`` injection point (``repro.testing.faults``) so
the chaos suite can kill the write at any stage — in particular inside the
torn window between the fully-written temp dir and the atomic rename — and
assert that ``latest_step`` only ever loads a complete checkpoint. The
stage names, in write order, are ``CRASH_STAGES``.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np

from ..testing import faults

_SEP = "/"

#: ``save_checkpoint`` crash-point stages, in the order they are hit (the
#: ``leaf`` stage fires once per leaf). ``pre_rename`` is the torn window:
#: temp dir complete, manifest written, final rename not yet issued.
CRASH_STAGES = ("post_tmp_dir", "leaf", "pre_rename", "post_rename")


def _crash_point(stage: str) -> None:
    """``ckpt.torn_write`` hook: one dict-emptiness check when quiet."""
    if faults.active():
        faults.raise_if("ckpt.torn_write", tag=stage)


def _flatten(tree: Any) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(_part(p) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _part(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return f"[{p.idx}]"
    return str(p)


def save_checkpoint(ckpt_dir: str, step: int, tree: Any, *, extra: Optional[dict] = None) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    _crash_point("post_tmp_dir")
    flat = _flatten(tree)
    manifest = {"step": step, "extra": extra or {}, "leaves": {}}
    for i, (key, arr) in enumerate(sorted(flat.items())):
        fname = f"leaf_{i:05d}.npy"
        dtype = str(arr.dtype)
        if dtype == "bfloat16":  # numpy can't round-trip ml_dtypes natively
            arr = arr.view(np.uint16)
        np.save(os.path.join(tmp, fname), arr)
        _crash_point("leaf")
        # stored_dtype records the on-disk view so restore can assert the
        # round-trip (bf16 is written as uint16 and viewed back).
        manifest["leaves"][key] = {"file": fname, "shape": list(arr.shape),
                                   "dtype": dtype,
                                   "stored_dtype": str(arr.dtype)}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    _crash_point("pre_rename")
    os.rename(tmp, final)
    _crash_point("post_rename")
    return final


def restore_checkpoint(ckpt_dir: str, template: Any, *, step: Optional[int] = None,
                       shardings: Any = None) -> tuple[int, Any]:
    """Restore into the *structure* of ``template``; ``shardings`` (same
    structure, NamedSharding or None leaves) places leaves on the new mesh."""
    step = latest_step(ckpt_dir) if step is None else step
    assert step is not None, f"no checkpoint under {ckpt_dir}"
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)

    leaves_by_key = manifest["leaves"]
    flat_template = jax.tree_util.tree_flatten_with_path(template)
    flat_shard = (jax.tree_util.tree_flatten_with_path(shardings)[0]
                  if shardings is not None else None)
    out = []
    for i, (pth, leaf) in enumerate(flat_template[0]):
        key = _SEP.join(_part(p) for p in pth)
        rec = leaves_by_key[key]
        arr = np.load(os.path.join(path, rec["file"]))
        stored = rec.get("stored_dtype", str(arr.dtype))
        if str(arr.dtype) != stored:
            raise ValueError(
                f"leaf {key!r}: on-disk dtype {arr.dtype} != recorded "
                f"stored_dtype {stored!r} — checkpoint corrupt or written "
                "by an incompatible version")
        if rec["dtype"] == "bfloat16":
            import ml_dtypes

            # uint16 view back to true bf16 — bit-exact round trip; the
            # assert locks the restored leaf to real bf16, not a raw view.
            arr = arr.view(ml_dtypes.bfloat16)
            assert arr.dtype == ml_dtypes.bfloat16
        sh = flat_shard[i][1] if flat_shard is not None else None
        out.append(jax.device_put(arr, sh) if sh is not None else jax.numpy.asarray(arr))
    return step, jax.tree_util.tree_unflatten(flat_template[1], out)


def checkpoint_extra(ckpt_dir: str, step: int) -> dict:
    """The ``extra`` metadata dict of a saved checkpoint, without loading
    any leaves — resumable fits read this first to validate the config
    hash before touching the (possibly large) accumulator arrays."""
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        return json.load(f).get("extra", {})


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


class AsyncCheckpointer:
    """Overlaps checkpoint IO with the next training steps (one in flight)."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._thread: Optional[threading.Thread] = None

    def save(self, step: int, tree: Any, *, extra: Optional[dict] = None) -> None:
        self.wait()
        host_tree = jax.tree.map(np.asarray, tree)  # snapshot before async write

        def _work():
            save_checkpoint(self.ckpt_dir, step, host_tree, extra=extra)
            self._gc()

        self._thread = threading.Thread(target=_work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = sorted(s for s in (latest_step(self.ckpt_dir),) if s is not None)
        all_steps = sorted(int(d.split("_")[1]) for d in os.listdir(self.ckpt_dir)
                           if d.startswith("step_") and not d.endswith(".tmp"))
        for s in all_steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.ckpt_dir, f"step_{s:08d}"), ignore_errors=True)
