from .ckpt import (CRASH_STAGES, AsyncCheckpointer, checkpoint_extra,
                   latest_step, restore_checkpoint, save_checkpoint)

__all__ = ["AsyncCheckpointer", "CRASH_STAGES", "checkpoint_extra",
           "latest_step", "restore_checkpoint", "save_checkpoint"]
