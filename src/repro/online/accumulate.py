"""Streamed normal-equation accumulators for durable / online FALKON.

The classic ``falkon_fit`` host path re-streams X once per CG iteration —
optimal for a one-shot fit (nothing is stored), but hostile to durability:
the solver state mid-fit is "somewhere inside CG", which cannot be
checkpointed at a meaningful boundary, and absorbing new rows means
starting over. This module trades one (M, M) array for both properties by
accumulating the normal-equation operator itself:

    H = K_nM^T K_nM   (M, M)        b = K_nM^T y   (M,) or (M, k)

in ONE deterministic chunk-order pass over the data (same associativity
every run — DESIGN.md §10), then solving

    (H + lam n K_MM) alpha = b

with the paper's Def. 2 preconditioner and the shared multi-RHS CG from
``repro.core.falkon``. Consequences:

  * **Checkpointable**: (H, b, cursor) at a chunk barrier is the *entire*
    fit state — fp32 ``.npy`` round-trips are bit-exact, so a resumed fit
    replays the remaining chunks into the same bits (repro/online/durable).
  * **Incremental**: new rows fold in as ``H += G^T G``; ``b += G^T y`` —
    O(batch) work, no re-streaming (``OnlineFalkon.append``).
  * **Warm refits**: the solve costs O(M^2 iters), independent of n — the
    data pass is paid once, not once per CG iteration. This is the >= 5x
    warm-vs-cold gap the ``online`` bench row gates.

The price is the usual normal-equations caveat: H is formed explicitly, so
the accumulator path agrees with the operator path to streamed-fp32 parity
(the documented 1e-4 scale-relative cross-backend tolerance), not bitwise.

Per-chunk absorption is delegated to an ``inner`` backend's ``gram_block``
(jnp / Pallas / shard_map), jit-compiled per chunk shape when the inner is
jit-safe — exactly the ``StreamBackend`` composition discipline.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from ..core.falkon import cg, make_preconditioner
from ..core.gram import Kernel
from ..stream.store import _TRACKER, device_chunks

Array = jax.Array

#: Times the fused accumulator solve was traced (a new (M, k, iters)
#: bucket). Warm-refit tests assert repeated same-shape refits do NOT bump
#: this — each refit is then one cached compiled call.
_ACC_SOLVE_TRACES = 0


def _absorb_chunk(kernel: Kernel, xb: Array, z: Array, yb: Array,
                  h: Array, b: Array, *, inner) -> tuple[Array, Array]:
    """Fold one (chunk, d) block into (H, b): H += G^T G, b += G^T y."""
    g = inner.gram_block(kernel, xb, z)
    return h + g.T @ g, b + g.T @ yb


_absorb_chunk_jit = partial(jax.jit, static_argnames=("inner",))(_absorb_chunk)


def absorb(kernel: Kernel, x, y, z: Array, h: Array, b: Array, *, inner,
           chunk: int | None = None) -> tuple[Array, Array]:
    """Fold rows (x, y) into the accumulators, chunk by chunk in row order.

    ``x``/``y`` may be host (numpy / ChunkStore-backed) or device arrays;
    chunks ride the double-buffered ``device_chunks`` iterator, so an
    appended batch larger than one chunk stays out-of-core. Accumulation
    order is the chunk order — deterministic, which is what makes the
    durable-fit resume bit-identical.
    """
    step = _absorb_chunk_jit if inner.jit_safe else _absorb_chunk
    for xb, yb in device_chunks(x, aux=y, chunk=chunk):
        _TRACKER.note_transient(4 * xb.shape[0] * z.shape[0])
        h, b = step(kernel, xb, z, yb, h, b, inner=inner)
    return h, b


@partial(jax.jit, static_argnames=("iters",))
def _acc_solve(kernel: Kernel, h: Array, b: Array, centers: Array,
               a_diag: Array, lam: Array, n: Array, *,
               iters: int) -> tuple[Array, Array]:
    """Preconditioned CG on the accumulated normal equations, one compiled
    program: (H + lam n K_MM) alpha = b with B from Def. 2. Everything is
    (M, M)-sized — no data pass. Returns (alpha, residual trajectory)."""
    global _ACC_SOLVE_TRACES
    _ACC_SOLVE_TRACES += 1
    prec = make_preconditioner(kernel, centers, a_diag, lam, n)
    kmm = kernel.cross(centers, centers).astype(jnp.float32)

    def matvec(v: Array) -> Array:
        u = prec.apply(v)
        return prec.apply_t(h @ u + lam * n * (kmm @ u))

    beta, resid = cg(matvec, prec.apply_t(b), iters, trajectory=True)
    return prec.apply(beta), resid


def solve_accumulators(kernel: Kernel, h: Array, b: Array, centers: Array,
                       lam: float, n: int, *, a_diag: Array | None = None,
                       iters: int = 20) -> tuple[Array, Array]:
    """Solve (H + lam n K_MM) alpha = b; returns (alpha, cg residuals).

    ``lam`` and ``n`` are traced (sweeping them never recompiles); ``iters``
    and the array shapes key the jit cache — repeated warm refits reuse one
    executable (see ``_ACC_SOLVE_TRACES``).
    """
    m = centers.shape[0]
    a_diag = (jnp.ones((m,), jnp.float32) if a_diag is None
              else jnp.asarray(a_diag, jnp.float32))
    return _acc_solve(kernel, h, b, centers, a_diag,
                      jnp.asarray(lam, jnp.float32),
                      jnp.asarray(n, jnp.float32), iters=iters)
