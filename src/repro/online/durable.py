"""Crash-resumable streamed FALKON fits (DESIGN.md §11).

``resumable_streamed_fit`` is the out-of-core fit with durability bolted on
at the only boundary where it is well-defined: the **chunk barrier**. After
every ``ckpt_every``-th chunk the complete solver state — the (M, M)/(M, k)
normal-equation accumulators, the chunk cursor, the caller's PRNG key and a
config hash — goes through the atomic-rename manifest machinery of
``repro.checkpoint`` (temp dir + ``os.rename``; ``latest_step`` can never
observe a torn write). A fit killed at chunk i restarts from the last
barrier <= i and replays the remaining chunks **into the same bits**:

  * fp32 leaves round-trip bit-exactly through ``.npy``,
  * chunk-order accumulation is deterministic (DESIGN.md §10),
  * the final solve is a pure function of (H, b, centers, lam, iters),

so resumed-alpha == uninterrupted-alpha exactly, not just to tolerance —
the chaos suite asserts bitwise equality.

Refusal policy: the checkpoint records a SHA-256 config hash over the
kernel, data shape/chunking, target shape, centers digest, a_diag digest,
lam, iters and the inner backend name. Resume re-derives the hash from the
live arguments and raises ``ResumeMismatchError`` on any difference — a
checkpoint from a different run is refused loudly, never silently blended.
Same for an accumulator shape mismatch (belt and suspenders: the hash
already covers shapes). Delete the checkpoint directory to start fresh.
"""
from __future__ import annotations

import hashlib
import json

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint import (checkpoint_extra, latest_step, restore_checkpoint,
                          save_checkpoint)
from ..core import health
from ..core.falkon import FalkonModel
from ..core.gram import BackendLike, Kernel, resolve_backend
from ..stream.store import ChunkStore
from .accumulate import absorb, solve_accumulators

Array = jax.Array

#: Checkpoint schema version — bumped on any layout change; a mismatch is a
#: refused resume, not a guess.
SCHEMA = 1


class ResumeMismatchError(RuntimeError):
    """A checkpoint's config hash / shapes do not match the live fit —
    resuming would silently blend incompatible runs, so we refuse."""


def _digest(arr) -> str:
    """SHA-256 of an array's dtype, shape and bytes (host-side)."""
    a = np.ascontiguousarray(np.asarray(arr))
    h = hashlib.sha256()
    h.update(str(a.dtype).encode())
    h.update(repr(a.shape).encode())
    h.update(a.tobytes())
    return h.hexdigest()


def fit_config_hash(kernel: Kernel, store: ChunkStore, centers, a_diag,
                    lam: float, iters: int, inner_name: str) -> str:
    """The identity of one durable fit: every input that shapes the
    accumulation or the solve. Two fits share a hash iff their checkpoints
    are interchangeable at a chunk barrier."""
    payload = {
        "schema": SCHEMA,
        "kernel": [kernel.name, float(kernel.sigma), float(kernel.kappa_sq)],
        "data": [int(store.shape[0]), int(store.shape[1]), int(store.chunk)],
        "targets": list(np.shape(store.y)),
        "centers": _digest(centers),
        "a_diag": _digest(a_diag),
        "lam": float(lam),
        "iters": int(iters),
        "inner": inner_name,
    }
    return hashlib.sha256(
        json.dumps(payload, sort_keys=True).encode()).hexdigest()


def resumable_streamed_fit(
    kernel: Kernel,
    x,
    y=None,
    centers: Array = None,
    lam: float = 1e-6,
    *,
    a_diag: Array | None = None,
    iters: int = 20,
    backend: BackendLike = "stream",
    ckpt_dir: str,
    ckpt_every: int = 4,
    key: Array | None = None,
) -> FalkonModel:
    """Out-of-core FALKON fit, checkpointed at chunk barriers.

    ``x`` is a ``ChunkStore`` carrying y, or a host/device array with ``y``
    given separately (a store is built). ``backend`` picks the per-tile
    Gram backend — ``"stream"`` / ``"stream:pallas"`` etc.; a non-stream
    spec is used directly as the tile builder. ``key``, if given, is a JAX
    PRNG key that rides the checkpoint (sampler-driven pipelines resume
    with the key they crashed with).

    On entry, if ``ckpt_dir`` holds a checkpoint: validate its config hash
    against the live arguments (``ResumeMismatchError`` on mismatch),
    restore (H, b, cursor) and replay only chunks >= cursor. The returned
    alpha is bit-identical to an uninterrupted fit — see module docstring.
    """
    if isinstance(x, ChunkStore):
        store = x
        if store.y is None:
            raise ValueError("resumable_streamed_fit needs targets; build "
                             "the ChunkStore with y")
    else:
        if y is None:
            raise ValueError("resumable_streamed_fit needs targets y")
        store = ChunkStore(x, y)
    n = store.shape[0]
    be = resolve_backend(backend, n=n)
    inner = getattr(be, "inner", be)
    centers = jnp.asarray(centers, jnp.float32)
    m = centers.shape[0]
    a_diag = (jnp.ones((m,), jnp.float32) if a_diag is None
              else jnp.asarray(a_diag, jnp.float32))
    cfg_hash = fit_config_hash(kernel, store, centers, a_diag, lam, iters,
                               getattr(inner, "name", "jnp"))

    k_shape = store.y.shape[1:]
    h = jnp.zeros((m, m), jnp.float32)
    b = jnp.zeros((m,) + k_shape, jnp.float32)
    key_data = (np.zeros((), np.uint32) if key is None
                else np.asarray(jax.random.key_data(key)))
    cursor = 0

    step0 = latest_step(ckpt_dir)
    if step0 is not None:
        extra = checkpoint_extra(ckpt_dir, step0)
        if extra.get("config_hash") != cfg_hash or extra.get("schema") != SCHEMA:
            raise ResumeMismatchError(
                f"checkpoint under {ckpt_dir!r} (step {step0}) was written "
                "by a different fit configuration (config hash "
                f"{extra.get('config_hash', '?')[:12]}... != "
                f"{cfg_hash[:12]}...); refusing to blend incompatible runs "
                "— delete the checkpoint directory to start fresh")
        _, tree = restore_checkpoint(
            ckpt_dir, {"h": h, "b": b, "key": key_data}, step=step0)
        if (tuple(tree["h"].shape) != (m, m)
                or tuple(tree["b"].shape) != (m,) + k_shape):
            raise ResumeMismatchError(
                f"checkpoint accumulators {tuple(tree['h'].shape)}/"
                f"{tuple(tree['b'].shape)} do not match the live fit "
                f"({(m, m)}/{(m,) + k_shape})")
        h, b = tree["h"], tree["b"]
        key_data = np.asarray(tree["key"])
        cursor = int(extra["cursor"])
        health.record_event("durable_fit_resume", step=step0, cursor=cursor)

    slices = store.chunk_slices()
    for i in range(cursor, len(slices)):
        sl = slices[i]
        h, b = absorb(kernel, store.x[sl], store.y[sl], centers, h, b,
                      inner=inner, chunk=store.chunk)
        done = i + 1
        if done == len(slices) or done % max(1, ckpt_every) == 0:
            save_checkpoint(
                ckpt_dir, done, {"h": h, "b": b, "key": key_data},
                extra={"config_hash": cfg_hash, "schema": SCHEMA,
                       "cursor": done, "rows": int(sl.stop)})

    alpha, resid = solve_accumulators(kernel, h, b, centers, lam, n,
                                      a_diag=a_diag, iters=iters)
    return FalkonModel(centers=centers, alpha=alpha, kernel=kernel,
                       backend=be,
                       diagnostics=health.SolveDiagnostics(resid),
                       lam=float(lam), n_train=n, a_diag=a_diag)
