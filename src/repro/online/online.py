"""Online FALKON: incremental appends, warm refits, background center
refresh (DESIGN.md §11).

``OnlineFalkon`` keeps the streamed normal-equation accumulators

    H = K_nM^T K_nM    b = K_nM^T y

live between fits. Incoming (x, y) rows are appended to the host
``ChunkStore`` and folded into (H, b) in O(batch) — no re-streaming of old
data — and a **warm refit** solves (H + lam n K_MM) alpha = b in
O(M^2 iters), independent of n: the data pass is paid once per row, ever.
The solve rides one cached jit executable (the fused accumulator solve),
so steady-state refits are a single compiled dispatch.

Ingest fence (always on): appended rows pass ``health.check_finite``
*before* touching the store or the accumulators — a NaN row is rejected
with the state untouched (accumulators are contaminated forever by one bad
row; the store could be repaired, the sums could not). The chaos suite
drives this with the ``online.corrupt_row`` injection point, which poisons
a row upstream of the fence.

Center refresh: the center set ages as the data distribution drifts, so
``refresh_centers`` re-draws it with any pluggable fast sampler (BLESS /
uniform / the spectral-approximation sampler — anything with the
``repro.api`` ``Sampler`` protocol's ``.sample``), then rebuilds (H, b)
against the new centers in one streamed pass. With ``background=True`` the
rebuild runs in a worker thread against a snapshot row count while the
foreground keeps appending/serving on the old accumulators;
``join_refresh`` absorbs the rows that arrived mid-rebuild (the delta) and
swaps the new state in. The refreshed model reaches live traffic via
``AsyncKrrServer.swap_model`` — probe-fenced, atomic at wave granularity.

Duck-typed sampler on purpose: ``repro.online`` sits below ``repro.api``
in the import order (api re-exports OnlineFalkon), so the sampler protocol
is structural here, never imported.
"""
from __future__ import annotations

import threading
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core import health
from ..core.falkon import FalkonModel
from ..core.gram import BackendLike, Kernel, resolve_backend
from ..stream.store import ChunkStore
from ..testing import faults
from .accumulate import absorb, solve_accumulators

Array = jax.Array


class OnlineFalkon:
    """Incrementally-updatable FALKON over a growing ``ChunkStore``.

    Args:
      kernel: the ``repro.core.gram.Kernel``.
      centers: initial (M, d) center set (e.g. a BLESS draw on the seed
        batch).
      lam: ridge regularization (paper convention, scaled by n at solve
        time — n is the *current* row count at each refit).
      x, y: the seed batch; ``x`` may be a ``ChunkStore`` already carrying
        y. Absorbed into the accumulators at construction.
      a_diag: sampler weights diag(A) for the preconditioner (None = I).
      iters: CG iterations per refit.
      backend: tile-builder spec ("stream", "stream:pallas", an instance,
        or None for the platform heuristic); also recorded on the fitted
        model for serving.
      sampler: optional default sampler for ``refresh_centers``.

    Attributes:
      model_: the latest refitted ``FalkonModel`` (None before ``refit``).
      counters: appends / rows / rejected / refits / refreshes — the
        provenance operators read alongside the serving stats.
    """

    def __init__(self, kernel: Kernel, centers, lam: float, *, x, y=None,
                 a_diag=None, iters: int = 20,
                 backend: BackendLike = "stream", sampler=None,
                 chunk: int | None = None):
        self.kernel = kernel
        self.lam = float(lam)
        self.iters = int(iters)
        self.sampler = sampler
        if isinstance(x, ChunkStore):
            if x.y is None:
                raise ValueError("OnlineFalkon needs targets; build the "
                                 "ChunkStore with y")
            self.store = x
        else:
            if y is None:
                raise ValueError("OnlineFalkon needs targets y")
            self.store = ChunkStore(x, y, chunk=chunk)
        self.backend = resolve_backend(backend, n=self.store.shape[0])
        self._inner = getattr(self.backend, "inner", self.backend)
        self.centers = jnp.asarray(centers, jnp.float32)
        m = self.centers.shape[0]
        if self.centers.ndim != 2 or self.centers.shape[1] != self.store.shape[1]:
            raise ValueError(f"centers must be (M, {self.store.shape[1]}), "
                             f"got {tuple(self.centers.shape)}")
        self.a_diag = (None if a_diag is None
                       else jnp.asarray(a_diag, jnp.float32))
        self._k_shape = self.store.y.shape[1:]
        self._h = jnp.zeros((m, m), jnp.float32)
        self._b = jnp.zeros((m,) + self._k_shape, jnp.float32)
        self._h, self._b = absorb(self.kernel, self.store.x, self.store.y,
                                  self.centers, self._h, self._b,
                                  inner=self._inner, chunk=self.store.chunk)
        self.model_: Optional[FalkonModel] = None
        self.counters = {"appends": 0, "rows": int(self.store.shape[0]),
                         "rejected": 0, "refits": 0, "refreshes": 0}
        self._refresh_thread: Optional[threading.Thread] = None
        self._refresh_result: Optional[tuple] = None
        self._refresh_error: Optional[BaseException] = None

    # -- ingest ---------------------------------------------------------------

    def append(self, x_new, y_new) -> int:
        """Absorb a batch of rows; returns the new total row count.

        The finite-input fence is always on: a batch carrying NaN/Inf (bit
        rot, a bad upstream join — or the ``online.corrupt_row`` chaos
        point) raises ``health.NonFiniteError`` with the store and the
        accumulators **untouched**. Rejections are counted and logged to
        the health event log.
        """
        x_new = jnp.asarray(x_new, jnp.float32)
        y_new = jnp.asarray(y_new, jnp.float32)
        d = self.store.shape[1]
        if x_new.ndim != 2 or x_new.shape[1] != d or x_new.shape[0] == 0:
            raise ValueError(f"append batch must be non-empty (r, {d}), "
                             f"got {tuple(x_new.shape)}")
        if (y_new.shape[0] != x_new.shape[0]
                or y_new.shape[1:] != self._k_shape):
            raise ValueError(f"append targets {tuple(y_new.shape)} do not "
                             f"match x rows {x_new.shape[0]} and output "
                             f"shape {tuple(self._k_shape)}")
        if faults.active():  # chaos: poison a row upstream of the fence
            x_new = faults.corrupt("online.corrupt_row", x_new)
        try:
            health.check_finite(x_new, "online append X")
            health.check_finite(y_new, "online append y")
        except health.NonFiniteError:
            self.counters["rejected"] += 1
            health.record_event("online_append_rejected",
                                rows=int(x_new.shape[0]))
            raise
        xh = np.asarray(x_new)
        yh = np.asarray(y_new)
        self.store.append(xh, yh)
        self._h, self._b = absorb(self.kernel, xh, yh, self.centers,
                                  self._h, self._b, inner=self._inner,
                                  chunk=self.store.chunk)
        self.counters["appends"] += 1
        self.counters["rows"] = int(self.store.shape[0])
        return self.counters["rows"]

    # -- refit ----------------------------------------------------------------

    def refit(self) -> FalkonModel:
        """Warm refit from the live accumulators: one cached compiled solve,
        O(M^2 iters), no data pass. Returns (and stores) the new model."""
        n = self.store.shape[0]
        alpha, resid = solve_accumulators(
            self.kernel, self._h, self._b, self.centers, self.lam, n,
            a_diag=self.a_diag, iters=self.iters)
        self.model_ = FalkonModel(
            centers=self.centers, alpha=alpha, kernel=self.kernel,
            backend=self.backend,
            diagnostics=health.SolveDiagnostics(resid),
            lam=self.lam, n_train=n,
            a_diag=(jnp.ones((self.centers.shape[0],), jnp.float32)
                    if self.a_diag is None else self.a_diag))
        self.counters["refits"] += 1
        return self.model_

    # -- center refresh --------------------------------------------------------

    def _build_refresh(self, key, sampler, n_snapshot: int):
        """Draw new centers and rebuild (H, b) over rows [0, n_snapshot)."""
        cs = sampler.sample(key, self.store, self.kernel,
                            backend=self.backend)
        m = int(cs.count)
        centers = jnp.asarray(self.store[np.asarray(cs.idx)[:m]], jnp.float32)
        a_diag = jnp.asarray(cs.weight[:m], jnp.float32)
        h = jnp.zeros((m, m), jnp.float32)
        b = jnp.zeros((m,) + self._k_shape, jnp.float32)
        h, b = absorb(self.kernel, self.store.x[:n_snapshot],
                      self.store.y[:n_snapshot], centers, h, b,
                      inner=self._inner, chunk=self.store.chunk)
        return centers, a_diag, h, b, n_snapshot

    def _install_refresh(self, result) -> None:
        """Swap refreshed state in, absorbing any rows appended since the
        snapshot (the delta) against the new centers first."""
        centers, a_diag, h, b, n_snapshot = result
        n_now = self.store.shape[0]
        if n_now > n_snapshot:
            h, b = absorb(self.kernel, self.store.x[n_snapshot:n_now],
                          self.store.y[n_snapshot:n_now], centers, h, b,
                          inner=self._inner, chunk=self.store.chunk)
        self.centers, self.a_diag = centers, a_diag
        self._h, self._b = h, b
        self.counters["refreshes"] += 1
        health.record_event("online_center_refresh",
                            m=int(centers.shape[0]), rows=n_now)

    def refresh_centers(self, key: Array, *, sampler=None,
                        background: bool = False) -> None:
        """Re-draw the center set and rebuild the accumulators against it.

        ``sampler`` (or the constructor default) is any object with the
        ``Sampler`` protocol's ``.sample(key, x, kernel, backend=...)``.
        Inline by default; with ``background=True`` the sampling + rebuild
        run in a worker thread over a snapshot of the current rows while
        appends continue against the old state — call ``join_refresh`` to
        absorb the delta and swap. The refreshed model only reaches traffic
        after the next ``refit`` (+ server ``swap_model``).
        """
        sampler = sampler if sampler is not None else self.sampler
        if sampler is None:
            raise ValueError("refresh_centers needs a sampler (argument or "
                             "constructor default)")
        if self._refresh_thread is not None:
            raise RuntimeError("a background refresh is already running; "
                               "join_refresh() it first")
        n_snapshot = self.store.shape[0]
        if not background:
            self._install_refresh(
                self._build_refresh(key, sampler, n_snapshot))
            return

        def _work():
            try:
                self._refresh_result = self._build_refresh(
                    key, sampler, n_snapshot)
            except BaseException as e:  # noqa: BLE001 — re-raised on join
                self._refresh_error = e

        self._refresh_thread = threading.Thread(target=_work, daemon=True)
        self._refresh_thread.start()

    def join_refresh(self) -> bool:
        """Wait for a background refresh and install it (delta-absorbed).
        Returns True if a refresh was installed, False if none was running.
        Re-raises any error the worker hit (old state stays live)."""
        if self._refresh_thread is None:
            return False
        self._refresh_thread.join()
        self._refresh_thread = None
        err, self._refresh_error = self._refresh_error, None
        result, self._refresh_result = self._refresh_result, None
        if err is not None:
            raise err
        self._install_refresh(result)
        return True
