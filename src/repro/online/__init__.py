"""Durable online FALKON: crash-resumable streamed fits, incremental
updates and warm refits over streamed normal-equation accumulators
(DESIGN.md §11). Sits above stream/checkpoint/core and below api."""
from .accumulate import absorb, solve_accumulators
from .durable import ResumeMismatchError, fit_config_hash, resumable_streamed_fit
from .online import OnlineFalkon

__all__ = ["OnlineFalkon", "ResumeMismatchError", "absorb",
           "fit_config_hash", "resumable_streamed_fit", "solve_accumulators"]
