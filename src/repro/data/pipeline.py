"""Deterministic, shard-aware, resumable data pipeline.

Every batch is a pure function of (seed, step): a restarted or rescaled job
replays identically from its checkpoint step with zero pipeline state to
save — the fault-tolerance story for data (DESIGN.md §5). Hosts slice their
own rows, so multi-host feeding needs no coordination.

``SyntheticLM`` produces *learnable* sequences (noisy affine next-token
rule over a random permutation) so the end-to-end example's loss actually
falls; ``TokenPipeline`` is the uniform-random load generator used by
benchmarks.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class TokenPipeline:
    vocab_size: int
    batch: int
    seq: int
    seed: int = 0

    def batch_at(self, step: int) -> dict:
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed), step)
        toks = jax.random.randint(key, (self.batch, self.seq + 1), 0, self.vocab_size)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


@dataclasses.dataclass(frozen=True)
class SyntheticLM:
    """Next token = perm[(a*t + b) % V] with prob (1-noise), uniform else."""

    vocab_size: int
    batch: int
    seq: int
    seed: int = 0
    noise: float = 0.1

    def _rule(self):
        key = jax.random.PRNGKey(self.seed)
        perm = jax.random.permutation(key, self.vocab_size)
        return perm

    def batch_at(self, step: int) -> dict:
        perm = self._rule()
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed + 1), step)
        k0, kn, ku = jax.random.split(key, 3)
        t0 = jax.random.randint(k0, (self.batch,), 0, self.vocab_size)

        def gen(tok, k):
            kn_, ku_ = jax.random.split(k)
            nxt = perm[tok]
            rand = jax.random.randint(ku_, tok.shape, 0, self.vocab_size)
            use_rand = jax.random.uniform(kn_, tok.shape) < self.noise
            nxt = jnp.where(use_rand, rand, nxt)
            return nxt, nxt

        keys = jax.random.split(kn, self.seq + 1)
        _, seqs = jax.lax.scan(gen, t0, keys)
        toks = jnp.concatenate([t0[None], seqs], 0).T  # (B, seq+2)
        return {"tokens": toks[:, : self.seq], "labels": toks[:, 1: self.seq + 1]}
