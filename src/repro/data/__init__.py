from .pipeline import SyntheticLM, TokenPipeline

__all__ = ["SyntheticLM", "TokenPipeline"]
