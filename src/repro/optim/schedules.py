"""LR schedules: cosine (default) and WSD (warmup-stable-decay, the MiniCPM
schedule — arXiv:2404.06395)."""
from __future__ import annotations

import jax.numpy as jnp


def cosine_schedule(step, *, peak_lr: float, warmup: int, total: int, floor: float = 0.1):
    step = jnp.asarray(step, jnp.float32)
    warm = peak_lr * step / jnp.maximum(warmup, 1)
    frac = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
    cos = peak_lr * (floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * frac)))
    return jnp.where(step < warmup, warm, cos)


def wsd_schedule(step, *, peak_lr: float, warmup: int, total: int,
                 decay_frac: float = 0.1, floor: float = 0.05):
    """Warmup -> flat -> sharp exponential-ish (linear here) decay tail."""
    step = jnp.asarray(step, jnp.float32)
    decay_start = total * (1.0 - decay_frac)
    warm = peak_lr * step / jnp.maximum(warmup, 1)
    tail = jnp.clip((step - decay_start) / jnp.maximum(total - decay_start, 1), 0.0, 1.0)
    dec = peak_lr * (1.0 - (1.0 - floor) * tail)
    return jnp.where(step < warmup, warm, jnp.where(step < decay_start, peak_lr, dec))


def make_schedule(name: str, **kw):
    fn = {"cosine": cosine_schedule, "wsd": wsd_schedule}[name]
    return lambda step: fn(step, **kw)
