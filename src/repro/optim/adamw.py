"""AdamW with fp32 master weights over bf16 compute params.

Memory layout per parameter (DESIGN.md §5): bf16 param (the model pytree) +
fp32 master + fp32 mu + fp32 nu, all sharded with the same PartitionSpec as
the parameter itself — FSDP over `data`, TP over `model`, replicated over
`pod` (the pod-axis all-reduce is where gradient compression applies,
repro.runtime.compress).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class OptConfig:
    peak_lr: float = 3e-4
    warmup: int = 100
    total_steps: int = 10_000
    schedule: str = "cosine"  # cosine | wsd
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0

    def lr(self, step):
        from .schedules import make_schedule

        return make_schedule(self.schedule, peak_lr=self.peak_lr, warmup=self.warmup,
                             total=self.total_steps)(step)


def adamw_init(params: Any) -> dict:
    # copy=True: fp32 param leaves must not alias the master (the train
    # step donates the whole TrainState — aliased buffers break donation)
    f32 = lambda p: jnp.array(p, jnp.float32, copy=True)
    return {
        "step": jnp.zeros((), jnp.int32),
        "master": jax.tree.map(f32, params),
        "mu": jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params),
        "nu": jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params),
    }


def opt_state_specs(pspecs: Any) -> dict:
    """Opt-state PartitionSpecs mirroring the param specs."""
    from jax.sharding import PartitionSpec as P

    return {"step": P(), "master": pspecs, "mu": pspecs, "nu": pspecs}


def global_norm(tree: Any) -> Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(params: Any, grads: Any, state: dict, cfg: OptConfig
                 ) -> tuple[Any, dict]:
    step = state["step"] + 1
    lr = cfg.lr(step)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))

    def upd(g, m, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu = cfg.b1 * mu + (1 - cfg.b1) * g
        nu = cfg.b2 * nu + (1 - cfg.b2) * g * g
        mu_hat = mu / (1 - cfg.b1**step.astype(jnp.float32))
        nu_hat = nu / (1 - cfg.b2**step.astype(jnp.float32))
        m = m - lr * (mu_hat / (jnp.sqrt(nu_hat) + cfg.eps) + cfg.weight_decay * m)
        return m, mu, nu

    flat = jax.tree.map(upd, grads, state["master"], state["mu"], state["nu"])
    master = jax.tree.map(lambda t: t[0], flat, is_leaf=lambda t: isinstance(t, tuple))
    mu = jax.tree.map(lambda t: t[1], flat, is_leaf=lambda t: isinstance(t, tuple))
    nu = jax.tree.map(lambda t: t[2], flat, is_leaf=lambda t: isinstance(t, tuple))
    new_params = jax.tree.map(lambda m, p: m.astype(p.dtype), master, params)
    return new_params, {"step": step, "master": master, "mu": mu, "nu": nu}
