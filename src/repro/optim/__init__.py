from .adamw import OptConfig, adamw_init, adamw_update, opt_state_specs
from .schedules import cosine_schedule, make_schedule, wsd_schedule

__all__ = ["OptConfig", "adamw_init", "adamw_update", "opt_state_specs",
           "cosine_schedule", "make_schedule", "wsd_schedule"]
