"""Cross-validated model selection as multi-RHS solves — the third slot.

``KFoldSweep`` turns the classic "k folds x L lambdas = k*L full fits" grid
into L multi-RHS FALKON solves: the k fold targets become k columns of ONE
block-CG (`repro.core.falkon`), sharing the sampled centers, the
preconditioner, the K_nM streaming, and — across the lambda grid — the
fused-fit jit cache (lam is traced, so every lambda after the first is a
cache hit with zero retraces).

Fold semantics — exact row-exclusion: column f solves exactly the system a
separate refit on the fold-f training rows would solve,

    (K_nM^T diag(m_f) K_nM + lam n_f K_MM) alpha_f = K_nM^T (m_f * y),

where m_f masks out fold f's rows and n_f = sum(m_f). The masks ride the
multi-RHS seam as an (n, folds) ``row_mask`` panel threaded through the
streamed quadratic op (one extra elementwise multiply per tile on every
backend — see ``Backend.knm_quadratic``), so held-out rows contribute
*nothing* to fold f's operator while all folds still share the one K_nM
stream, the sampled centers, the preconditioner, and the fused-fit jit
cache. tests/test_scenarios.py pins the per-fold scores to naive
``falkon_fit(x[train], y[train], ...)`` refits at 1e-6.

Migration note: before PR 9 this class used the "fold-masked RHS"
approximation (held-out targets zeroed but their K_nM rows kept in the
operator — full-data n in the regularization). Scores from that era are
systematically lower-variance than exact CV scores; re-run sweeps rather
than comparing across the change. The lambda *ranking* rarely moves, but
absolute MSE values do.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp

from ..core.gram import BackendLike, Kernel
from ..core.leverage import CenterSet
from .estimators import FalkonRegressor, FitConfig
from .samplers import BlessSampler, Sampler

Array = jax.Array


def fold_ids(key: Array, n: int, folds: int) -> Array:
    """Random balanced fold assignment: (n,) int32 in [0, folds).

    A random permutation dealt round-robin, so fold sizes differ by at most
    one row.
    """
    perm = jax.random.permutation(key, n)
    return jnp.zeros((n,), jnp.int32).at[perm].set(jnp.arange(n) % folds)


@dataclasses.dataclass(frozen=True)
class KFoldResult:
    """Scores of one ``KFoldSweep.run``.

    Attributes:
      lams: the swept regularization grid, in run order.
      scores: (len(lams), folds) fp32 — held-out MSE of fold f's column at
        each lambda (column f is scored only on rows assigned to fold f).
      fold_id: (n,) int32 fold assignment used, for reproducing splits.
      center_set: the shared sampled ``CenterSet`` every solve rode on.
    """

    lams: tuple[float, ...]
    scores: Array
    fold_id: Array
    center_set: CenterSet

    @property
    def mean_scores(self) -> Array:
        """(len(lams),) — per-lambda MSE averaged over folds."""
        return jnp.mean(self.scores, axis=1)

    @property
    def best_index(self) -> int:
        """Index into ``lams`` with the lowest mean held-out MSE."""
        return int(jnp.argmin(self.mean_scores))

    @property
    def best_lam(self) -> float:
        """The selected regularization: ``lams[best_index]``."""
        return self.lams[self.best_index]


@dataclasses.dataclass
class KFoldSweep:
    """Exact k-fold lambda selection where folds are columns of one solve.

    One sampler call picks the shared centers; then each lambda costs a
    single multi-RHS fused fit (folds = RHS columns on the k-bucketed
    cache, each column exactly excluding its held-out rows via the
    ``row_mask`` panel) plus one panel predict — against
    ``folds * len(lams)`` full fits for the naive grid, at identical
    scores (1e-6 parity; see the module docstring).

    Attributes:
      kernel: a ``Kernel`` or a registered family name ("gaussian", ...).
      sampler: center sampler (slot 1); default ``BlessSampler()``.
      lams: regularization grid for the solver (the paper's lam_falkon).
      folds: number of cross-validation folds (= RHS columns per solve).
      sigma: bandwidth when ``kernel`` is given by name.
      iters: CG iterations per solve.
      backend: kernel-operator backend spec (instance, name, or None).
      seed: PRNG seed for sampling and fold assignment when ``run`` gets
        no explicit key.

    Example::

        sweep = KFoldSweep(kernel="gaussian", sigma=2.0,
                           lams=(1e-3, 1e-5, 1e-7), folds=5)
        res = sweep.run(x, y)
        best = res.best_lam            # lowest mean held-out MSE
    """

    kernel: Kernel | str = "gaussian"
    sampler: Sampler | None = None
    lams: Sequence[float] = (1e-3, 1e-5, 1e-7)
    folds: int = 5
    sigma: float = 1.0
    iters: int = 20
    backend: BackendLike = None
    seed: int = 0

    def run(self, x: Array, y: Array, *, key: Array | None = None,
            center_set: CenterSet | None = None) -> KFoldResult:
        """Sweep the lambda grid; returns per-fold/per-lambda held-out MSE.

        ``x`` (n, d) and single-output ``y`` (n,) fp32; ``center_set``
        bypasses the sampler with a precomputed (J, A). The first lambda
        pays the one sampler call and the one fused-fit compile; every
        further lambda is a cache-hit multi-RHS solve.
        """
        x = jnp.asarray(x)
        y = jnp.asarray(y)
        if y.ndim != 1:
            raise ValueError(f"KFoldSweep needs single-output y (n,), got {y.shape}; "
                             "the fold columns occupy the RHS axis")
        if not 2 <= self.folds <= y.shape[0]:
            raise ValueError(f"folds must be in [2, n], got {self.folds}")
        key = jax.random.PRNGKey(self.seed) if key is None else key
        k_sample, k_fold = jax.random.split(key)
        fid = fold_ids(k_fold, y.shape[0], self.folds)
        # column f trains on exactly the rows outside fold f: the mask panel
        # excludes held-out rows from the quadratic operator AND the targets
        # (exact row-exclusion CV — see the module docstring).
        train_mask = (fid[:, None] != jnp.arange(self.folds)[None, :]).astype(y.dtype)
        y_panel = y[:, None] * train_mask
        est = FalkonRegressor(
            kernel=self.kernel, sigma=self.sigma,
            sampler=self.sampler if self.sampler is not None else BlessSampler(),
            warm_start=True)
        scores = []
        for i, lam in enumerate(self.lams):
            est.config = FitConfig(lam=lam, iters=self.iters,
                                   backend=self.backend, seed=self.seed)
            est.fit(x, y_panel, key=k_sample,
                    center_set=center_set if i == 0 else None,
                    row_mask=train_mask)
            pred = est.predict(x)  # (n, folds): one panel knm_matvec
            sq = (pred - y[:, None]) ** 2
            held_out = fid[:, None] == jnp.arange(self.folds)[None, :]
            scores.append(jnp.sum(sq * held_out, axis=0)
                          / jnp.sum(held_out, axis=0))
        return KFoldResult(lams=tuple(float(ell) for ell in self.lams),
                           scores=jnp.stack(scores),
                           fold_id=fid,
                           center_set=est.center_set_)


__all__ = ["KFoldSweep", "KFoldResult", "fold_ids"]
