"""Composable leverage-score samplers — the first slot of the paper's pipeline.

The paper's algorithm is two pluggable stages: a sampler producing a weighted
Nystrom center set (J, A) and a solver consuming it. Every sampler here
implements one protocol:

    sample(key, x, kernel, *, backend=None) -> CenterSet

so BLESS (Alg. 1), BLESS-R (Alg. 2), the Sec. 2.3 baselines and the exact
oracle are drop-in interchangeable inside ``FalkonRegressor`` /
``NystromRegressor`` — swap the sampler, keep everything else. All heavy work
routes through the kernel-operator ``Backend`` seam, so any sampler runs on
jnp / Pallas / shard_map unchanged.

Samplers are frozen dataclasses: hashable, comparable by configuration, and
safe to share across estimators.
"""
from __future__ import annotations

import dataclasses
from typing import Protocol, runtime_checkable

import jax
import jax.numpy as jnp

from ..core.baselines import recursive_rls, squeak, two_pass
from ..core.bless import BlessResult, _bucket, _multinomial, bless, bless_r
from ..core.chen_yang import fast_spectral_rls
from ..core.gram import BackendLike, Kernel
from ..core.leverage import CenterSet, exact_rls, uniform_center_set
from ..core.sampling import gumbel_topk

Array = jax.Array


def as_prng_key(key) -> Array:
    """Normalize every accepted seed spelling to one typed-key convention.

    Accepts a Python int seed, a new-style typed key (``jax.random.key``),
    or a legacy (2,) uint32 ``PRNGKey`` array; returns a typed key. Every
    ``Sampler.sample`` funnels its key through this, so
    ``sampler.sample(0, ...)``, ``sample(jax.random.key(0), ...)`` and
    ``sample(jax.random.PRNGKey(0), ...)`` draw identical center sets.
    """
    if isinstance(key, int):
        return jax.random.key(key)
    key = jnp.asarray(key)
    if jnp.issubdtype(key.dtype, jax.dtypes.prng_key):
        return key
    return jax.random.wrap_key_data(key.astype(jnp.uint32))


@runtime_checkable
class Sampler(Protocol):
    """Anything that maps (key, data, kernel) to a weighted center set."""

    def sample(self, key: Array, x: Array, kernel: Kernel, *,
               backend: BackendLike = None) -> CenterSet:
        """Return (J, A) as a padded ``CenterSet`` (idx/weight/mask/count)."""
        ...


@dataclasses.dataclass(frozen=True)
class BlessSampler:
    """BLESS (paper Alg. 1): bottom-up ladder, sampling with replacement.

    Parameters mirror ``repro.core.bless.bless``; ``lam`` is the sampler's
    own regularization scale — keep it well above the solver's lam (the
    paper's lam_bless >> lam_falkon trick, Sec. 4).
    """

    lam: float = 1e-3
    q: float = 2.0
    q1: float = 3.0
    q2: float = 3.0
    lam0: float | None = None
    t: float = 1.0
    m_cap: int | None = None

    def ladder(self, key: Array, x: Array, kernel: Kernel, *,
               backend: BackendLike = None) -> BlessResult:
        """The full regularization path (every BlessLevel), for introspection."""
        return bless(as_prng_key(key), x, kernel, self.lam, q=self.q, q1=self.q1,
                     q2=self.q2, lam0=self.lam0, t=self.t, m_cap=self.m_cap,
                     backend=backend)

    def sample(self, key: Array, x: Array, kernel: Kernel, *,
               backend: BackendLike = None) -> CenterSet:
        """Run Alg. 1 and return the final level's weighted (J, A)."""
        return self.ladder(key, x, kernel, backend=backend).final.centers


@dataclasses.dataclass(frozen=True)
class BlessRSampler:
    """BLESS-R (paper Alg. 2): rejection sampling, without replacement."""

    lam: float = 1e-3
    q: float = 2.0
    q2: float = 3.0
    lam0: float | None = None
    t: float = 1.0
    m_cap: int | None = None

    def ladder(self, key: Array, x: Array, kernel: Kernel, *,
               backend: BackendLike = None) -> BlessResult:
        """The full regularization path (every BlessLevel), for introspection."""
        return bless_r(as_prng_key(key), x, kernel, self.lam, q=self.q, q2=self.q2,
                       lam0=self.lam0, t=self.t, m_cap=self.m_cap, backend=backend)

    def sample(self, key: Array, x: Array, kernel: Kernel, *,
               backend: BackendLike = None) -> CenterSet:
        """Run Alg. 2 and return the final level's weighted (J, A)."""
        return self.ladder(key, x, kernel, backend=backend).final.centers


@dataclasses.dataclass(frozen=True)
class UniformSampler:
    """Uniform column sampling [5] — the fastest, highest-variance baseline.

    ``weights="nystrom"`` sets A = (M/n) I (the Eq. 3 scoring convention of
    ``uniform_center_set``); ``weights="identity"`` sets A = I (the classic
    FALKON-uniform preconditioner of the paper's experiments). ``replace``
    switches between i.i.d. draws and a without-replacement choice.
    """

    m: int
    weights: str = "nystrom"  # "nystrom" (A = M/n I) | "identity" (A = I)
    replace: bool = True

    def sample(self, key: Array, x: Array, kernel: Kernel, *,
               backend: BackendLike = None) -> CenterSet:
        """Draw m uniform centers from x's rows (weights per ``weights``)."""
        if self.weights not in ("nystrom", "identity"):
            raise ValueError(f"weights must be 'nystrom' or 'identity', got {self.weights!r}")
        key = as_prng_key(key)
        n = x.shape[0]
        if self.replace:
            idx = jax.random.randint(key, (self.m,), 0, n)
        else:
            idx = jax.random.choice(key, n, (self.m,), replace=False)
        cs = uniform_center_set(idx, n, _bucket(self.m))  # owns the padding rules
        if self.weights == "identity":
            cs = cs._replace(weight=jnp.ones_like(cs.weight))
        return cs


@dataclasses.dataclass(frozen=True)
class ExactRlsSampler:
    """The O(n^3) oracle: M i.i.d. draws from the *exact* ridge leverage
    score distribution (Eq. 1) — the gold standard every approximate sampler
    is measured against. Weights follow the Alg. 1 line-10 convention with
    the candidate set = [n]: A = M diag(p_{j_1}, ..., p_{j_M})."""

    m: int
    lam: float = 1e-3

    def sample(self, key: Array, x: Array, kernel: Kernel, *,
               backend: BackendLike = None) -> CenterSet:
        """m i.i.d. draws from the exact Eq. 1 leverage distribution."""
        scores = exact_rls(kernel, x, self.lam)
        p = scores / jnp.sum(scores)
        mbuf = _bucket(self.m)
        pos = _multinomial(as_prng_key(key), p, mbuf)
        mask = jnp.arange(mbuf) < self.m
        return CenterSet(
            idx=pos.astype(jnp.int32),
            weight=jnp.where(mask, self.m * p[pos], 1.0).astype(jnp.float32),
            mask=mask,
            count=jnp.asarray(self.m, jnp.int32),
        )


# ---------------------------------------------------------------------------
# Related-work samplers (Sec. 2.3 baselines) — the drop-in alternatives the
# slot structure exists for: Musco & Musco's RECURSIVE-RLS [9], SQUEAK [8],
# and El Alaoui & Mahoney's two-pass [6], each wrapped over repro.core.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class RecursiveRlsSampler:
    """RECURSIVE-RLS [9] (Musco & Musco) as a drop-in Sampler."""

    lam: float = 1e-3
    q2: float = 2.0
    depth: int | None = None
    m_cap: int | None = None

    def sample(self, key: Array, x: Array, kernel: Kernel, *,
               backend: BackendLike = None) -> CenterSet:
        """Run RECURSIVE-RLS over the halving tree; returns its (J, A)."""
        return recursive_rls(as_prng_key(key), x, kernel, self.lam, q2=self.q2,
                             depth=self.depth, m_cap=self.m_cap, backend=backend)


@dataclasses.dataclass(frozen=True)
class SqueakSampler:
    """SQUEAK [8] (Calandriello, Lazaric & Valko) as a drop-in Sampler."""

    lam: float = 1e-3
    qbar: float = 2.0
    n_chunks: int | None = None
    m_cap: int | None = None

    def sample(self, key: Array, x: Array, kernel: Kernel, *,
               backend: BackendLike = None) -> CenterSet:
        """Run SQUEAK's streaming merge; returns its weighted (J, A)."""
        return squeak(as_prng_key(key), x, kernel, self.lam, qbar=self.qbar,
                      n_chunks=self.n_chunks, m_cap=self.m_cap, backend=backend)


@dataclasses.dataclass(frozen=True)
class TwoPassSampler:
    """Two-pass sampling [6] (El Alaoui & Mahoney) as a drop-in Sampler."""

    lam: float = 1e-3
    m2: int = 256
    m1: int | None = None

    def sample(self, key: Array, x: Array, kernel: Kernel, *,
               backend: BackendLike = None) -> CenterSet:
        """Pass 1: uniform pilot scores; pass 2: the m2 weighted draws."""
        return two_pass(as_prng_key(key), x, kernel, self.lam, m1=self.m1,
                        m2=self.m2, backend=backend)


@dataclasses.dataclass(frozen=True)
class ChenYangSampler:
    """Chen & Yang (2021) fast statistical leverage approximation.

    One-shot spectral RLS estimate (``repro.core.chen_yang``): uniformly
    sketch m0 landmark columns, eigendecompose twice, read every point's
    score off the Nystrom factor — O(n m0^2), no ladder or rejection
    rounds. The ``m`` centers are then drawn *without* replacement by
    Gumbel-top-k proportionally to the estimated scores, with
    inclusion-rate weights A_jj = min(m l^_j / sum l^, 1) (the Eq. 3
    convention for without-replacement sets, as in BLESS-R).
    """

    m: int
    lam: float = 1e-3
    m0: int | None = None  # landmark count; None -> default_sketch_size(n)

    def scores(self, key: Array, x: Array, kernel: Kernel, *,
               backend: BackendLike = None) -> Array:
        """The (n,) spectral RLS estimates themselves, for introspection."""
        return fast_spectral_rls(as_prng_key(key), kernel, x, self.lam,
                                 m0=self.m0, backend=backend)

    def sample(self, key: Array, x: Array, kernel: Kernel, *,
               backend: BackendLike = None) -> CenterSet:
        """Sketch, score, and draw m distinct centers ~ l^ (Gumbel-top-k)."""
        k_sketch, k_draw = jax.random.split(as_prng_key(key))
        s = fast_spectral_rls(k_sketch, kernel, x, self.lam, m0=self.m0,
                              backend=backend)
        sel = gumbel_topk(k_draw, s, self.m)
        pi = jnp.minimum(self.m * s[sel] / jnp.sum(s), 1.0)
        mbuf = _bucket(self.m)
        pad = mbuf - self.m
        return CenterSet(
            idx=jnp.pad(sel, (0, pad)).astype(jnp.int32),
            weight=jnp.pad(pi, (0, pad), constant_values=1.0).astype(jnp.float32),
            mask=jnp.arange(mbuf) < self.m,
            count=jnp.asarray(self.m, jnp.int32),
        )


__all__ = [
    "Sampler", "as_prng_key", "BlessSampler", "BlessRSampler", "UniformSampler",
    "ExactRlsSampler", "RecursiveRlsSampler", "SqueakSampler", "TwoPassSampler",
    "ChenYangSampler",
]
