"""sklearn-style estimators over the paper's solvers — the second slot.

Every estimator follows the same contract:

    est = FalkonRegressor(sampler=BlessSampler(lam=1e-3), kernel="gaussian",
                          config=FitConfig(lam=1e-5, iters=20, backend="jnp"))
    est.fit(X, y)          # -> est  (learned attrs get a trailing underscore)
    est.predict(X)         # (n,) or (n, k), through the backend seam
    est.score(X, y)        # R^2 (uniform average over outputs)

``FitConfig`` is a frozen dataclass so a configuration is hashable and
shareable; the estimator itself is mutable sklearn-style (swap ``.config``
between fits for a lambda sweep). ``y`` may be (n,) or (n, k): multi-output
targets ride ONE multi-RHS block-CG against the shared centers — the
preconditioner, the K_nM streaming and the fused-fit compile are shared, so
extra output columns are nearly free (GEMM flops only).

Warm starts: with ``warm_start=True`` a refit on same-shaped X reuses the
previously sampled centers, so consecutive ``fit`` calls ride the PR 2
fused-fit jit cache — same shape bucket, zero recompiles, one fused dispatch
per refit (lam and the kernel bandwidth are traced, so sweeping them is free).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from ..core.falkon import FalkonModel, falkon_fit
from ..core.gram import BackendLike, Kernel, make_kernel
from ..core.leverage import CenterSet
from ..core.nystrom import exact_krr, nystrom_krr
from ..stream import ChunkStore
from .samplers import BlessSampler, Sampler

Array = jax.Array


def _as_data(x) -> Array | ChunkStore:
    """Device array for array inputs; a host-resident ``ChunkStore`` passes
    through untouched so the streaming paths (falkon_fit's host CG, the
    samplers, predict) keep X out of device memory. The direct O(n^2+) paths
    (``ExactKrr``) still ``jnp.asarray`` explicitly — materializing there is
    the algorithm, not an accident."""
    return x if isinstance(x, ChunkStore) else jnp.asarray(x)


@dataclasses.dataclass(frozen=True)
class FitConfig:
    """Solver configuration shared by every estimator.

    Attributes:
      lam: the solver's ridge regularization (the paper's lambda; keep it
        well below a BLESS sampler's own lam — Sec. 4).
      iters: CG iteration count (FALKON only; the direct solvers ignore it).
      backend: kernel-operator backend spec — instance, registry name
        ("jnp" | "pallas" | "sharded" | "guarded"), or None for the
        platform heuristic.
      seed: PRNG seed for the sampler when ``fit`` is not given a key.
      check_finite: arm the §9 finite-output fence on FALKON fits (the
        direct solvers are always fenced); costs one host sync per fit, so
        it is off by default on this hot path.
    """

    lam: float = 1e-3
    iters: int = 20
    backend: BackendLike = None
    seed: int = 0
    check_finite: bool = False


def _as_kernel(kernel: Kernel | str, sigma: float) -> Kernel:
    return kernel if isinstance(kernel, Kernel) else make_kernel(kernel, sigma=sigma)


class _KrrEstimator:
    """Shared fit bookkeeping + predict/score for the three estimators."""

    def __init__(self, kernel: Kernel | str = "gaussian", *, sigma: float = 1.0,
                 config: FitConfig | None = None):
        self.kernel = _as_kernel(kernel, sigma)
        self.config = config if config is not None else FitConfig()
        self.model_: FalkonModel | None = None

    # -- sklearn surface -----------------------------------------------------

    def predict(self, x: Array, *, return_std: bool = False) -> Array | tuple[Array, Array]:
        """Predictions through the kernel-operator seam ((n,) or (n, k)).

        With ``return_std=True`` returns ``(pred, std)`` where ``std`` is
        the (n,) square root of the GP-style Nystrom posterior variance
        (``predictive_variance``) — shared across output columns, since it
        does not depend on y.
        """
        if self.model_ is None:
            raise RuntimeError(f"{type(self).__name__} is not fitted; call .fit first")
        pred = self.model_.predict(_as_data(x), backend=self.config.backend)
        if not return_std:
            return pred
        return pred, jnp.sqrt(self.predictive_variance(x))

    def predictive_variance(self, x: Array) -> Array:
        """GP-style posterior variance ``k(x,x) - k_xM (K_MM + lam n A)^{-1}
        k_Mx`` per row of ``x`` ((n,), nonnegative), streamed through the
        backend seam — works at out-of-core n on ``StreamBackend``."""
        if self.model_ is None:
            raise RuntimeError(f"{type(self).__name__} is not fitted; call .fit first")
        return self.model_.predictive_variance(_as_data(x),
                                               backend=self.config.backend)

    def score(self, x: Array, y: Array) -> float:
        """Coefficient of determination R^2 (uniform average over outputs)."""
        y = jnp.asarray(y)
        pred = self.predict(x)
        if y.shape != pred.shape:  # e.g. (n, 1) targets on a (n,) model:
            raise ValueError(       # broadcasting would yield a garbage R^2
                f"y has shape {y.shape} but the model predicts {pred.shape}")
        res = jnp.sum((y - pred) ** 2, axis=0)
        tot = jnp.maximum(jnp.sum((y - jnp.mean(y, axis=0)) ** 2, axis=0), 1e-30)
        return float(jnp.mean(1.0 - res / tot))

    def _key(self, key: Array | None) -> Array:
        return jax.random.PRNGKey(self.config.seed) if key is None else key


class FalkonRegressor(_KrrEstimator):
    """FALKON (Sec. 3) with a pluggable center sampler.

    ``sampler`` fills the pipeline's first slot (defaults to ``BlessSampler``,
    i.e. FALKON-BLESS); the sampled ``CenterSet``'s weights become the
    generalized preconditioner's A (Def. 2). ``warm_start=True`` keeps the
    sampled centers across refits on same-shaped X (see module docstring).
    """

    def __init__(self, kernel: Kernel | str = "gaussian", *,
                 sampler: Sampler | None = None, sigma: float = 1.0,
                 config: FitConfig | None = None, warm_start: bool = False):
        super().__init__(kernel, sigma=sigma, config=config)
        self.sampler = sampler if sampler is not None else BlessSampler()
        self.warm_start = warm_start
        self.centers_: Array | None = None
        self.a_diag_: Array | None = None
        self.center_set_: CenterSet | None = None
        self._fit_shape_: tuple | None = None

    def fit(self, x: Array, y: Array, *, key: Array | None = None,
            center_set: CenterSet | None = None,
            callback: Callable[[int, FalkonModel], None] | None = None,
            row_mask: Array | None = None) -> "FalkonRegressor":
        """Sample centers (unless warm-starting) and solve by preconditioned
        CG. ``center_set`` bypasses the sampler with a precomputed (J, A)
        (e.g. one BLESS ladder shared across estimators); ``callback(i,
        model)`` switches to the host CG loop for per-iteration metrics
        (single-output only). ``row_mask`` (shaped like y) gives each RHS
        column its own training-row subset — the exact row-exclusion CV
        mechanism ``KFoldSweep`` rides (see ``falkon_fit``)."""
        x = _as_data(x)
        y = jnp.asarray(y)
        cfg = self.config
        # warm start contract (sklearn-style): the caller asserts X is the
        # same training set as the previous fit. The guard can only check
        # shape — a different dataset with identical (n, d) is on the
        # caller; pass center_set= (or leave warm_start off) when rotating
        # datasets, e.g. cross-validation folds.
        reuse = (center_set is None and self.warm_start
                 and self.centers_ is not None
                 and self._fit_shape_ == x.shape)
        if not reuse:
            cs = center_set if center_set is not None else self.sampler.sample(
                self._key(key), x, self.kernel, backend=cfg.backend)
            m = int(cs.count)
            self.center_set_ = cs
            self.centers_ = x[cs.idx[:m]]
            self.a_diag_ = cs.weight[:m]
            self._fit_shape_ = x.shape
        self.model_ = falkon_fit(self.kernel, x, y, self.centers_, cfg.lam,
                                 a_diag=self.a_diag_, iters=cfg.iters,
                                 backend=cfg.backend, callback=callback,
                                 check_finite=cfg.check_finite,
                                 row_mask=row_mask)
        return self


class FalkonClassifier(FalkonRegressor):
    """One-vs-rest classification as ONE multi-RHS FALKON solve.

    The k classes become k RHS columns of a single block-CG on shared
    centers (squared loss on +-1 one-hot targets — the least-squares SVM
    reading): the preconditioner, every K_nM stream, and the fused-fit
    compile are paid once, so k-class classification costs the k-output
    regression price, not k independent fits. Warm-start refits ride the
    same fused-fit cache as the regressor.

    ``predict`` returns labels from ``self.classes_`` (argmax of the margin
    panel); ``decision_function`` exposes the raw (n, k) margins;
    ``predict_proba`` is a softmax over the margins — a monotone
    calibration convenience, not a fitted probability model; ``score`` is
    accuracy. Binary problems keep both columns (k = 2) so every class has
    a margin.
    """

    #: sorted unique training labels; set by ``fit``.
    classes_: "np.ndarray | None" = None

    def fit(self, x: Array, y: Array, *, key: Array | None = None,
            center_set: CenterSet | None = None,
            callback: Callable[[int, FalkonModel], None] | None = None,
            row_mask: Array | None = None) -> "FalkonClassifier":
        """Encode labels as a +-1 one-hot panel and fit the multi-RHS solve.

        ``y`` is (n,) labels of any hashable dtype (ints, strings, ...);
        the sorted unique labels become ``self.classes_``. ``callback`` is
        unsupported (the panel fit has no single-output host loop).
        """
        if callback is not None:
            raise ValueError("FalkonClassifier fits a multi-RHS panel; "
                             "per-iteration callback is single-output only")
        labels = np.asarray(y)
        if labels.ndim != 1:
            raise ValueError(f"classifier targets must be (n,) labels, "
                             f"got shape {labels.shape}")
        classes, inv = np.unique(labels, return_inverse=True)
        if classes.shape[0] < 2:
            raise ValueError("need at least 2 classes to classify")
        self.classes_ = classes
        onehot = (inv[:, None] == np.arange(classes.shape[0])[None, :])
        panel = jnp.asarray(np.where(onehot, 1.0, -1.0), jnp.float32)
        super().fit(x, panel, key=key, center_set=center_set,
                    row_mask=row_mask)
        return self

    def decision_function(self, x: Array) -> Array:
        """Raw one-vs-rest margins (n, k) through the panel predict."""
        return super().predict(x)

    def predict(self, x: Array, *, return_std: bool = False):
        """Predicted labels (n,) from ``classes_[argmax(margins)]``; with
        ``return_std=True`` also the (n,) posterior std of the margins."""
        margins = self.decision_function(x)
        labels = self.classes_[np.asarray(jnp.argmax(margins, axis=1))]
        if not return_std:
            return labels
        return labels, jnp.sqrt(self.predictive_variance(x))

    def predict_proba(self, x: Array) -> Array:
        """Softmax over the margins, (n, k) rows summing to 1 — a monotone
        score calibration (ranking-faithful), not fitted probabilities."""
        return jax.nn.softmax(self.decision_function(x), axis=1)

    def score(self, x: Array, y: Array) -> float:
        """Classification accuracy in [0, 1]."""
        return float(np.mean(np.asarray(self.predict(x)) == np.asarray(y)))


class NystromRegressor(_KrrEstimator):
    """Direct Nystrom-KRR (Def. 4) on sampled centers — the O(n M^2) dense
    solve FALKON's CG converges to; same sampler slot, no iteration knob."""

    def __init__(self, kernel: Kernel | str = "gaussian", *,
                 sampler: Sampler | None = None, sigma: float = 1.0,
                 config: FitConfig | None = None):
        super().__init__(kernel, sigma=sigma, config=config)
        self.sampler = sampler if sampler is not None else BlessSampler()
        self.centers_: Array | None = None
        self.center_set_: CenterSet | None = None

    def fit(self, x: Array, y: Array, *, key: Array | None = None) -> "NystromRegressor":
        """Sample centers and solve Def. 4 directly; ``y`` (n,) or (n, k)."""
        x = _as_data(x)
        cs = self.sampler.sample(self._key(key), x, self.kernel,
                                 backend=self.config.backend)
        m = int(cs.count)
        self.center_set_ = cs
        self.centers_ = x[cs.idx[:m]]
        self.model_ = nystrom_krr(self.kernel, x, jnp.asarray(y), self.centers_,
                                  self.config.lam, backend=self.config.backend)
        return self


class ExactKrr(_KrrEstimator):
    """Exact kernel ridge regression (Eq. 12) — the O(n^3) oracle. No
    sampler slot: every training point is a center."""

    def fit(self, x: Array, y: Array, *, key: Array | None = None) -> "ExactKrr":
        """Solve Eq. 12 on the full Gram matrix; ``y`` (n,) or (n, k)."""
        self.model_ = exact_krr(self.kernel, jnp.asarray(x), jnp.asarray(y),
                                self.config.lam, backend=self.config.backend)
        return self


__all__ = ["FitConfig", "FalkonRegressor", "FalkonClassifier",
           "NystromRegressor", "ExactKrr"]
