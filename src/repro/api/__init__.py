"""``repro.api`` — the single public front door.

The paper's pipeline is two pluggable stages; this package makes each a
first-class slot:

  * **Samplers** (Alg. 1/2 + baselines + oracle) — anything implementing
    ``Sampler.sample(key, x, kernel, *, backend=None) -> CenterSet``.
  * **Estimators** — ``FalkonRegressor`` (Sec. 3 CG), ``FalkonClassifier``
    (one-vs-rest on one multi-RHS solve), ``NystromRegressor`` (Def. 4
    direct), ``ExactKrr`` (Eq. 12 oracle), all sklearn-style
    ``fit(X, y) -> self`` / ``predict`` / ``score`` with multi-output ``y``,
    warm-start refits on the fused-fit cache, and GP-style predictive
    uncertainty (``predict(x, return_std=True)`` / ``predictive_variance``).
  * **Kernel families** — the extensible registry behind ``Kernel``:
    gaussian / laplacian / linear / matern32 / cauchy built in, each running
    on all three backends (jnp / Pallas / shard_map) from one definition
    (``register_kernel_family``; recipe in DESIGN.md §7).
  * **Model selection** — ``KFoldSweep`` scores a lambda grid by *exact*
    row-exclusion k-fold cross-validation where the k folds are columns of
    ONE multi-RHS FALKON solve per lambda (per-column row masks in the
    streamed quadratic op; shared centers, preconditioner and K_nM
    streaming; the lambda grid rides the fused-fit cache).
  * **Serving** — ``KrrServer`` micro-batches prediction traffic over a
    fitted estimator or model; ``AsyncKrrServer`` (+ ``ServeConfig``) adds
    the fault-tolerant continuous-batching loop: bounded queue with
    backpressure, per-request deadlines, wave-level failure isolation,
    SLO-triggered degradation to a fallback model, and probe-fenced
    zero-downtime ``swap_model`` (DESIGN.md §9/§11, docs/serving.md).
  * **Durable online FALKON** — ``OnlineFalkon`` absorbs incoming rows into
    streamed normal-equation accumulators (fenced ingest, warm O(M^2)
    refits, pluggable background center refresh);
    ``resumable_streamed_fit`` checkpoints the out-of-core fit at chunk
    barriers and resumes a killed fit to a bit-identical alpha
    (``ResumeMismatchError`` refuses incompatible checkpoints) —
    DESIGN.md §11.

    from repro.api import BlessSampler, FalkonRegressor, FitConfig

    est = FalkonRegressor(kernel="matern32", sigma=2.0,
                          sampler=BlessSampler(lam=1e-3, m_cap=400),
                          config=FitConfig(lam=1e-5, iters=20))
    est.fit(x, y)
    yhat = est.predict(x_test)

Everything here is re-exported from the implementing modules; ``__all__``
is the supported surface (guarded by tests/test_api.py — no core internals
leak through this namespace).
"""
from ..core.gram import Kernel, make_kernel
from ..core.leverage import CenterSet
from ..families import KernelFamily, kernel_family_names, register_kernel_family
from ..online import OnlineFalkon, ResumeMismatchError, resumable_streamed_fit
from ..serving.async_krr import AsyncKrrServer, ServeConfig
from ..serving.krr import KrrServer
from ..stream import ChunkStore, StreamBackend
from .estimators import (ExactKrr, FalkonClassifier, FalkonRegressor,
                         FitConfig, NystromRegressor)
from .samplers import (
    BlessRSampler,
    BlessSampler,
    ChenYangSampler,
    ExactRlsSampler,
    RecursiveRlsSampler,
    Sampler,
    SqueakSampler,
    TwoPassSampler,
    UniformSampler,
    as_prng_key,
)
from .sweep import KFoldResult, KFoldSweep

__all__ = [
    # samplers (slot 1)
    "Sampler", "as_prng_key", "BlessSampler", "BlessRSampler", "UniformSampler",
    "ExactRlsSampler", "RecursiveRlsSampler", "SqueakSampler", "TwoPassSampler",
    "ChenYangSampler",
    # estimators (slot 2)
    "FitConfig", "FalkonRegressor", "FalkonClassifier", "NystromRegressor",
    "ExactKrr",
    # model selection (slot 3)
    "KFoldSweep", "KFoldResult",
    # kernel families
    "Kernel", "make_kernel", "KernelFamily", "register_kernel_family",
    "kernel_family_names",
    # shared data type + serving
    "CenterSet", "KrrServer", "AsyncKrrServer", "ServeConfig",
    # out-of-core streaming (DESIGN.md §10)
    "ChunkStore", "StreamBackend",
    # durable online FALKON (DESIGN.md §11)
    "OnlineFalkon", "resumable_streamed_fit", "ResumeMismatchError",
]
