"""Fast statistical leverage approximation (Chen & Yang, 2021).

One-shot spectral estimate of the ridge leverage scores: uniformly
subsample m0 landmark columns S, eigendecompose the (m0, m0) landmark Gram,
and read every point's score off the Nystrom factor

    C = K_nS V diag(mu)^{-1/2},        K_SS = V diag(mu) V^T,
    C^T C = U diag(s) U^T,
    l^_i  = sum_j (C U)_ij^2 / (s_j + lam n).

(The Nystrom approximation K^ = C C^T has eigenpairs (s_j, C U_j s_j^{-1/2}),
so the sum is exactly [K^ (K^ + lam n I)^{-1}]_ii.) Total cost O(n m0^2 +
m0^3) with two small eigh's — no ladder, no rejection rounds. Compared to
BLESS it trades the multiplicative (1 +- t) guarantee for a single
fixed-size sketch; it shines as a scorer when one pass over the data is all
the budget allows.

Both Gram blocks go through the kernel-operator ``Backend`` seam, so the
estimator runs on the jnp / Pallas / shard_map paths like every other
scorer. Exposed to users as ``repro.api.ChenYangSampler``.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

from .gram import BackendLike, Kernel, resolve_backend
from .leverage import _SCORE_FLOOR

Array = jax.Array


def default_sketch_size(n: int) -> int:
    """Landmark count heuristic: ~4 sqrt(n), floored at 64, capped at n."""
    return min(n, max(64, 4 * int(math.ceil(math.sqrt(n)))))


def _fast_spectral_rls_impl(kernel, x, sel, lam, *, backend):
    n = x.shape[0]
    xs = x[sel]
    kss = backend.gram_block(kernel, xs, xs).astype(jnp.float32)
    mu, v = jnp.linalg.eigh(kss)
    # floor the landmark spectrum: near-null directions of K_SS carry no
    # signal and would otherwise blow up the whitening mu^{-1/2}
    mu = jnp.maximum(mu, 1e-6 * jnp.maximum(jnp.max(mu), 1.0))
    kns = backend.gram_block(kernel, x, xs).astype(jnp.float32)
    c = kns @ (v / jnp.sqrt(mu)[None, :])
    s, u = jnp.linalg.eigh(c.T @ c)
    cu = c @ u
    scores = jnp.sum(cu * cu / (jnp.maximum(s, 0.0) + lam * n)[None, :], axis=1)
    return jnp.clip(scores, _SCORE_FLOOR, 1.0)


_fast_spectral_rls = partial(jax.jit,
                             static_argnames=("backend",))(_fast_spectral_rls_impl)


def fast_spectral_rls(
    key: Array,
    kernel: Kernel,
    x: Array,
    lam: float,
    *,
    m0: int | None = None,
    backend: BackendLike = None,
) -> Array:
    """Chen & Yang's one-shot spectral RLS estimate for every point.

    Args:
      key: PRNG key (drives the uniform landmark subsample).
      kernel: bounded PSD kernel.
      x: (n, d) dataset.
      lam: regularization (the paper's lambda).
      m0: landmark count; default ``default_sketch_size(n)``.
      backend: kernel-operator backend (instance, registry name, or None
        for the platform heuristic).

    Returns:
      (n,) fp32 scores in [_SCORE_FLOOR, 1].
    """
    n = x.shape[0]
    backend = resolve_backend(backend, n=n)
    m0 = default_sketch_size(n) if m0 is None else min(n, int(m0))
    sel = jax.random.permutation(key, n)[:m0].astype(jnp.int32)
    fn = _fast_spectral_rls if backend.jit_safe else _fast_spectral_rls_impl
    return fn(kernel, x, sel, jnp.asarray(lam, jnp.float32), backend=backend)
