"""Core paper contribution: BLESS / BLESS-R leverage score sampling and the
FALKON-BLESS kernel ridge regression solver, plus the baselines they are
measured against."""
from .gram import Kernel, make_kernel, blocked_cross, sq_dists
from .leverage import (
    CenterSet,
    approx_rls,
    approx_rls_all,
    effective_dim,
    exact_rls,
    uniform_center_set,
)
from .bless import BlessLevel, BlessResult, bless, bless_r, lam_ladder, theory_constants
from .baselines import recursive_rls, squeak, two_pass, uniform_centers
from .falkon import (
    FalkonModel,
    Preconditioner,
    cg,
    falkon_bless_fit,
    falkon_fit,
    local_knm_quadratic,
    local_knm_t,
    make_preconditioner,
)
from .nystrom import exact_krr, nystrom_krr

__all__ = [
    "Kernel", "make_kernel", "blocked_cross", "sq_dists",
    "CenterSet", "approx_rls", "approx_rls_all", "effective_dim", "exact_rls",
    "uniform_center_set",
    "BlessLevel", "BlessResult", "bless", "bless_r", "lam_ladder", "theory_constants",
    "recursive_rls", "squeak", "two_pass", "uniform_centers",
    "FalkonModel", "Preconditioner", "cg", "falkon_bless_fit", "falkon_fit",
    "local_knm_quadratic", "local_knm_t", "make_preconditioner",
    "exact_krr", "nystrom_krr",
]
