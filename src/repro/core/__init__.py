"""Core paper contribution: BLESS / BLESS-R leverage score sampling and the
FALKON-BLESS kernel ridge regression solver, plus the baselines they are
measured against. All hot contractions go through the kernel-operator
``Backend`` seam (jnp / Pallas / shard_map) in ``repro.core.backend``.

The composable public surface (Sampler / Estimator objects, kernel-family
registry) lives one level up in ``repro.api``; this package remains the
implementation layer those objects delegate to."""
from .gram import (
    Kernel,
    KernelFamily,
    make_kernel,
    blocked_cross,
    sq_dists,
    backend_names,
    kernel_family_names,
    register_backend,
    register_kernel_family,
    resolve_backend,
)
from .backend import (
    Backend,
    JnpBackend,
    PallasBackend,
    ShardedBackend,
    default_backend,
)
from .leverage import (
    CenterSet,
    approx_rls,
    approx_rls_all,
    effective_dim,
    exact_rls,
    uniform_center_set,
)
from .bless import BlessLevel, BlessResult, bless, bless_r, lam_ladder, theory_constants
from .baselines import recursive_rls, squeak, two_pass, uniform_centers
from .chen_yang import default_sketch_size, fast_spectral_rls
from .sampling import categorical, gumbel_topk
from .falkon import (
    FalkonModel,
    Preconditioner,
    cg,
    falkon_bless_fit,
    falkon_fit,
    local_knm_quadratic,
    local_knm_t,
    make_preconditioner,
)
from .nystrom import exact_krr, nystrom_krr

__all__ = [
    "Kernel", "KernelFamily", "make_kernel", "blocked_cross", "sq_dists",
    "kernel_family_names", "register_kernel_family",
    "Backend", "JnpBackend", "PallasBackend", "ShardedBackend",
    "backend_names", "default_backend", "register_backend", "resolve_backend",
    "CenterSet", "approx_rls", "approx_rls_all", "effective_dim", "exact_rls",
    "uniform_center_set",
    "BlessLevel", "BlessResult", "bless", "bless_r", "lam_ladder", "theory_constants",
    "recursive_rls", "squeak", "two_pass", "uniform_centers",
    "categorical", "gumbel_topk", "default_sketch_size", "fast_spectral_rls",
    "FalkonModel", "Preconditioner", "cg", "falkon_bless_fit", "falkon_fit",
    "local_knm_quadratic", "local_knm_t", "make_preconditioner",
    "exact_krr", "nystrom_krr",
]
