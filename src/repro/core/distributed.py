"""Distributed BLESS / FALKON over a (data,)-sharded dataset.

The paper's only distributed story is "SQUEAK with p machines"; here both
phases are jax-native SPMD (DESIGN.md §2):

  * FALKON CG matvec  v -> K_nM^T (K_nM v):  X and y are row-sharded over the
    ``data`` mesh axis; each device runs the fused local Gram-matvec and the
    (M,) partials are ``psum``-ed — the exact collective schedule of a DP
    gradient all-reduce, so it inherits XLA's overlap machinery.
  * BLESS candidate scoring lives behind the backend seam:
    ``repro.core.backend.ShardedBackend.masked_quadform`` (candidates
    row-sharded, the (Mbuf, Mbuf) Cholesky factor replicated — it is
    <= d_eff^2 by the paper's own space bound).

Everything here works on a 1-device mesh too, which is how the unsharded
tests exercise it; tests/test_distributed.py re-runs on 8 forced host
devices in a subprocess.
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from .falkon import FalkonModel
from .gram import Kernel

Array = jax.Array


def data_mesh(axis: str = "data") -> Mesh:
    """1-D mesh over all local devices (the core library's DP mesh)."""
    devs = jax.devices()
    return jax.make_mesh((len(devs),), (axis,))


def shard_rows(mesh: Mesh, x: Array, axis: str = "data") -> Array:
    """Place a (n, ...) array row-sharded; pads n up to the axis size."""
    p = (-x.shape[0]) % mesh.shape[axis]
    if p:
        x = jnp.pad(x, ((0, p),) + ((0, 0),) * (x.ndim - 1))
    return jax.device_put(x, NamedSharding(mesh, P(axis, *([None] * (x.ndim - 1)))))


def dist_knm_quadratic(mesh: Mesh, kernel: Kernel, x_sharded: Array, z: Array,
                       n_valid: int, axis: str = "data", *,
                       mask: Array | None = None) -> Callable[[Array], Array]:
    """Returns v -> K_nM^T (K_nM v) with X row-sharded over ``axis``.

    ``v`` may be (M,) or an (M, k) panel (replicated either way): each
    device contracts its local Gram block against every column, and the
    psum-ed partial is (M,) or (M, k) accordingly.

    ``mask`` — optional per-column row-exclusion weights, row-sharded like
    X ((n,) or an (n, k) panel): column j computes K_nM^T diag(m_j) K_nM
    v_j, the exact-CV form, as one extra elementwise multiply on the local
    (rows, k) intermediate before the psum.
    """
    n_pad = x_sharded.shape[0]

    @jax.jit
    def op(v: Array) -> Array:
        def local(xl: Array, vl: Array) -> Array:
            rows = jax.lax.axis_index(axis) * (n_pad // mesh.shape[axis]) + jnp.arange(xl.shape[0])
            g = kernel.cross(xl, z) * (rows < n_valid)[:, None]
            return jax.lax.psum(g.T @ (g @ vl), axis)

        def local_masked(xl: Array, ml: Array, vl: Array) -> Array:
            rows = jax.lax.axis_index(axis) * (n_pad // mesh.shape[axis]) + jnp.arange(xl.shape[0])
            g = kernel.cross(xl, z) * (rows < n_valid)[:, None]
            t = g @ vl
            t = t * (ml if t.ndim == ml.ndim else ml[:, None])
            return jax.lax.psum(g.T @ t, axis)

        if mask is None:
            return shard_map(local, mesh=mesh, in_specs=(P(axis, None), P()),
                             out_specs=P())(x_sharded, v)
        mspec = P(axis, *([None] * (mask.ndim - 1)))
        return shard_map(local_masked, mesh=mesh,
                         in_specs=(P(axis, None), mspec, P()),
                         out_specs=P())(x_sharded, mask, v)

    return op


def dist_knm_t(mesh: Mesh, kernel: Kernel, x_sharded: Array, y_sharded: Array, z: Array,
               n_valid: int, axis: str = "data") -> Array:
    """K_nM^T y with X, y row-sharded; ``y`` (n,) -> (M,), (n, k) -> (M, k)."""
    n_pad = x_sharded.shape[0]

    def local(xl: Array, yl: Array) -> Array:
        rows = jax.lax.axis_index(axis) * (n_pad // mesh.shape[axis]) + jnp.arange(xl.shape[0])
        valid = rows < n_valid
        yl = jnp.where(valid if yl.ndim == 1 else valid[:, None], yl, 0.0)
        return jax.lax.psum(kernel.cross(xl, z).T @ yl, axis)

    return jax.jit(shard_map(local, mesh=mesh, in_specs=(P(axis, None), P(axis)),
                             out_specs=P()))(x_sharded, y_sharded)


def _knm_matvec_local(kernel: Kernel, xl: Array, z: Array, v: Array) -> Array:
    return kernel.cross(xl, z) @ v


@functools.lru_cache(maxsize=None)
def _dist_knm_matvec_fn(mesh: Mesh, axis: str):
    """Jitted shard_map'd predict contraction, cached per (mesh, axis) so the
    serving hot path compiles once per wave shape, not once per call."""
    return jax.jit(shard_map(
        _knm_matvec_local, mesh=mesh,
        in_specs=(P(), P(axis, None), P(), P()), out_specs=P(axis)))


def dist_knm_matvec(mesh: Mesh, kernel: Kernel, x_sharded: Array, z: Array, v: Array,
                    n_valid: int, axis: str = "data") -> Array:
    """K_nM v with X row-sharded — the predict contraction. ``v`` may be
    (M,) or an (M, k) panel (one local Gram evaluation serves all columns).
    The output is row-parallel (each device owns its rows), so no collective
    is needed; padded rows produce values that are sliced off."""
    return _dist_knm_matvec_fn(mesh, axis)(kernel, x_sharded, z, v)[:n_valid]


def falkon_fit_distributed(mesh: Mesh, kernel: Kernel, x: Array, y: Array, centers: Array,
                           lam: float, *, a_diag: Array | None = None, iters: int = 20,
                           axis: str = "data") -> FalkonModel:
    """Data-parallel FALKON: X/y sharded over ``axis``, (M,*) state replicated.

    Thin wrapper: ``falkon_fit`` with a ``ShardedBackend`` pinned to ``mesh``
    — the backend stages X/y once (shard_rows) and serves both CG
    contractions through the same dist_* collectives defined above.
    """
    from .backend import ShardedBackend
    from .falkon import falkon_fit

    return falkon_fit(kernel, x, y, centers, lam, a_diag=a_diag, iters=iters,
                      backend=ShardedBackend(axis=axis, mesh=mesh))
