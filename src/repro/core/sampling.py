"""Jitted sampling primitives for the fused BLESS ladder.

Two draw schemes cover every sampler in the repo:

  * ``categorical`` — M i.i.d. with-replacement draws from an (unnormalized)
    weight vector, via inverse-CDF on sorted uniforms. This is BLESS Alg. 1
    line 9 (Multinomial(P_h, U_h)): the paper samples *with* replacement, so
    a Gumbel-top-k is the wrong distribution here (top-k is without
    replacement) and the per-draw Gumbel-argmax equivalent would need
    M x R noise values where inverse-CDF needs M uniforms. DESIGN.md §8
    spells out the semantics.
  * ``gumbel_topk`` — weighted sampling *without* replacement by the Gumbel
    trick: argtop-k of ``log w_i + G_i`` with i.i.d. standard Gumbel noise
    draws k distinct indices with the successive-conditional probabilities
    of weighted sampling without replacement. One (R,) noise vector, fully
    jittable, no host round trip.

Both take raw (>= 0) weights, mask invalid slots via ``-inf`` logits, and
are deterministic given the key, so cross-backend center-set parity
(tests/test_backend.py) reduces to fp-closeness of the score vectors.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

Array = jax.Array

_WEIGHT_FLOOR = 1e-30  # keeps the CDF strictly increasing on valid slots


@partial(jax.jit, static_argnames=("m",))
def categorical(key: Array, weights: Array, m: int) -> Array:
    """``m`` i.i.d. draws from ``p = weights / sum(weights)`` (inverse-CDF).

    ``weights`` (R,) are unnormalized and may contain exact zeros (padded
    slots); zero-mass cells are never selected. Returns (m,) int32 indices
    into the weight buffer.
    """
    cdf = jnp.cumsum(jnp.maximum(weights, 0.0))
    cdf = cdf / jnp.maximum(cdf[-1], _WEIGHT_FLOOR)
    u = jax.random.uniform(key, (m,))
    return jnp.searchsorted(cdf, u).astype(jnp.int32)


@partial(jax.jit, static_argnames=("k",))
def gumbel_topk(key: Array, weights: Array, k: int) -> Array:
    """Weighted sampling of ``k`` distinct indices without replacement.

    Perturbs ``log weights`` with i.i.d. Gumbel noise and takes the top-k
    (the Gumbel-max trick); slots with weight <= 0 get ``-inf`` logits and
    are only drawn if fewer than ``k`` valid slots exist. Returns (k,)
    int32 indices, descending by perturbed logit.
    """
    logw = jnp.where(weights > 0.0, jnp.log(jnp.maximum(weights, _WEIGHT_FLOOR)),
                     -jnp.inf)
    g = jax.random.gumbel(key, logw.shape)
    _, idx = jax.lax.top_k(logw + g, k)
    return idx.astype(jnp.int32)
