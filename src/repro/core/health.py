"""Numerical health fences for the solver stack (DESIGN.md §9).

Three failure domains get a fence here:

  * **Factorization** — ``chol_with_jitter_ladder`` replaces the old
    one-shot jitter retry with an escalating ladder (jitter ``eps * 10^k``
    for ``k = 0 .. JITTER_LEVELS-1``, eps trace-scaled) and *reports the
    level used*, so callers can log how sick their K_MM was.
    ``safe_cholesky`` is the host-level wrapper with the fence armed: it
    either returns a finite factor or raises ``FactorizationError`` —
    never a silent NaN.
  * **Iteration** — ``SolveDiagnostics`` classifies a CG residual
    trajectory (converged / stalled / diverged) lazily on host access;
    ``repro.core.falkon`` records the trajectory on every fit and surfaces
    it as ``FalkonModel.diagnostics``.
  * **Outputs** — ``check_finite`` is the boundary fence: one blocking
    ``isfinite`` reduce, raising ``NonFiniteError`` instead of letting a
    NaN propagate into downstream consumers (serving waves, benchmarks).

Fence placement policy (the why lives in DESIGN.md §9): fences sit at
*boundaries that already materialize their result* (serving wave scatter,
the direct oracle solvers) so the happy path pays no extra device syncs;
the hot fused-fit sweep path keeps its fence opt-in
(``falkon_fit(check_finite=True)`` / ``FitConfig(check_finite=True)``).

Recoveries that should be visible in aggregate (jitter escalations,
backend fallbacks, wave failures) are appended to a bounded in-process
event log — ``record_event`` / ``events`` / ``clear_events`` — so tests
and operators can ask "how often are we limping?" without scraping logs.
"""
from __future__ import annotations

import collections
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


class HealthError(RuntimeError):
    """Base class for solver/serving health-fence failures."""


class FactorizationError(HealthError):
    """A Cholesky factorization stayed NaN through the whole jitter ladder."""


class NonFiniteError(HealthError):
    """A finite-output fence caught NaN/Inf at a layer boundary."""


# ---------------------------------------------------------------------------
# Escalating-jitter Cholesky ladder
# ---------------------------------------------------------------------------

#: Ladder length: attempt k uses jitter eps0 * 10^k with eps0 = 1e-6 * the
#: mean diagonal. k = JITTER_LEVELS-1 therefore adds ~10x the mean diagonal —
#: a matrix that is still indefinite past that point is not meaningfully PSD
#: and the fence should fire rather than keep inflating the regularizer.
JITTER_LEVELS = 8


def chol_with_jitter_ladder(a: Array) -> tuple[Array, Array]:
    """Cholesky with escalating trace-scaled jitter; returns (chol, level).

    Attempt ``k`` factors ``a + eps0 * 10^k * I`` (``eps0 = 1e-6 * mean
    diag``); the ladder stops at the first finite factor. ``level`` is the
    int32 number of the successful attempt (0 = base jitter sufficed). If
    every level fails the factor is returned as-is (NaN) with
    ``level == JITTER_LEVELS - 1`` — jit-safe code cannot raise, so the
    host-level fences (``safe_cholesky``, ``check_finite``) own the raise.

    jit-safe: the escalation is a ``lax.while_loop``, so the common path
    pays exactly one factorization and the retries are only *computed* when
    the previous level produced NaN.
    """
    eps0 = jnp.maximum(1e-6 * jnp.mean(jnp.diagonal(a)), 1e-30)
    eye = jnp.eye(a.shape[0], dtype=a.dtype)

    def attempt(k: Array) -> Array:
        jitter = eps0 * jnp.power(10.0, k.astype(a.dtype))
        return jnp.linalg.cholesky(a + jitter * eye)

    def cond(state):
        k, chol = state
        return jnp.logical_and(jnp.any(jnp.isnan(chol)), k < JITTER_LEVELS - 1)

    def body(state):
        k, _ = state
        return k + 1, attempt(k + 1)

    k0 = jnp.asarray(0, jnp.int32)
    level, chol = jax.lax.while_loop(cond, body, (k0, attempt(k0)))
    return chol, level


def safe_cholesky(a: Array, *, what: str = "matrix") -> tuple[Array, int]:
    """Host-level ladder with the fence armed: finite factor or raise.

    Returns ``(chol, level)`` with ``level`` a Python int (the jitter level
    used, 0 = base). Raises ``FactorizationError`` if the factor is still
    NaN after the whole ladder — this function never returns NaN silently.
    Escalations (level > 0) are appended to the health event log. Not
    jit-safe (it blocks on the NaN flag); traced code uses
    ``chol_with_jitter_ladder`` and fences at the boundary instead.
    """
    chol, level = chol_with_jitter_ladder(a)
    lvl = int(level)
    if not bool(jnp.all(jnp.isfinite(chol))):
        record_event("factorization_failure", what=what, level=lvl)
        raise FactorizationError(
            f"Cholesky of {what} ({a.shape[0]}x{a.shape[1]}) stayed non-finite "
            f"after {JITTER_LEVELS} escalating jitter levels (up to ~10x the "
            "mean diagonal); the matrix is not numerically PSD")
    if lvl > 0:
        record_event("jitter_escalation", what=what, level=lvl)
    return chol, lvl


# ---------------------------------------------------------------------------
# Finite-output fence
# ---------------------------------------------------------------------------


def check_finite(x: Array, what: str = "array") -> Array:
    """Boundary fence: return ``x`` unchanged or raise ``NonFiniteError``.

    One blocking ``isfinite`` reduce — callers place it where the result is
    about to be materialized anyway (serving wave scatter, oracle solvers)
    or behind an opt-in flag on hot paths (see module docstring).
    """
    if not bool(jnp.all(jnp.isfinite(x))):
        bad = int(jnp.sum(~jnp.isfinite(x)))
        record_event("non_finite", what=what, bad=bad)
        raise NonFiniteError(
            f"{what} contains {bad} non-finite value(s) "
            f"(shape {tuple(x.shape)}); refusing to propagate")
    return x


# ---------------------------------------------------------------------------
# CG residual-trajectory diagnostics
# ---------------------------------------------------------------------------

#: A residual that ever exceeds this factor over its initial value means the
#: "SPD" operator/preconditioner pair is broken — CG on a true SPD system is
#: monotone in the energy norm and near-monotone in the residual.
DIVERGENCE_FACTOR = 1e2
#: Converged: squared residual reduced below this fraction of initial
#: (sqrt ~ 1e-4 relative residual — solid for fp32 downstream use).
CONVERGED_REL = 1e-8
#: Stalled: the second half of the run improved the squared residual by
#: less than this factor while still far from converged.
STALL_IMPROVEMENT = 0.5


class SolveDiagnostics(NamedTuple):
    """Residual-trajectory health report for one CG solve.

    ``residuals`` holds the squared preconditioned-residual norms, shape
    (iters+1,) for a single RHS or (iters+1, k) for a multi-RHS panel
    (row 0 is the initial residual). All classification properties fetch
    lazily on first access — building the diagnostics at fit time costs no
    device sync.
    """

    residuals: Array

    def _np(self) -> np.ndarray:
        r = np.asarray(self.residuals, dtype=np.float64)
        return r[:, None] if r.ndim == 1 else r

    @property
    def reduction(self) -> np.ndarray:
        """Per-column final/initial squared-residual ratio, shape (k,)."""
        r = self._np()
        return r[-1] / np.maximum(r[0], 1e-300)

    @property
    def converged(self) -> bool:
        """Every column reduced its squared residual below CONVERGED_REL."""
        return bool(np.all(self.reduction < CONVERGED_REL))

    @property
    def diverged(self) -> bool:
        """Some column's residual blew past DIVERGENCE_FACTOR x initial."""
        r = self._np()
        return bool(np.any(np.max(r, axis=0) > DIVERGENCE_FACTOR * np.maximum(r[0], 1e-300)))

    @property
    def stalled(self) -> bool:
        """Some column made < STALL_IMPROVEMENT progress over the second
        half of the run while still unconverged (and did not diverge)."""
        if self.diverged:
            return False
        r = self._np()
        mid = r[r.shape[0] // 2]
        tail = r[-1] / np.maximum(mid, 1e-300)
        unconverged = self.reduction >= CONVERGED_REL
        return bool(np.any(unconverged & (tail > STALL_IMPROVEMENT)))

    def summary(self) -> str:
        """One-line human-readable verdict (forces the residual fetch)."""
        state = ("diverged" if self.diverged else
                 "converged" if self.converged else
                 "stalled" if self.stalled else "progressing")
        worst = float(np.max(self.reduction))
        return (f"cg {state}: {self.residuals.shape[0] - 1} iters, "
                f"worst residual reduction {worst:.3e}")


# ---------------------------------------------------------------------------
# Health event log
# ---------------------------------------------------------------------------

_EVENTS: collections.deque = collections.deque(maxlen=512)


def record_event(kind: str, **info: Any) -> None:
    """Append a recovery/failure event to the bounded in-process log."""
    _EVENTS.append({"kind": kind, **info})


def events(kind: str | None = None) -> list[dict]:
    """Snapshot of recorded events, optionally filtered by ``kind``."""
    return [e for e in _EVENTS if kind is None or e["kind"] == kind]


def clear_events() -> None:
    """Drop all recorded events (tests isolate themselves with this)."""
    _EVENTS.clear()
