"""Competing leverage-score samplers from the paper's Sec. 2.3.

These exist so Table 1 / Fig. 1 / Fig. 2 analogues can be benchmarked against
BLESS with a shared scoring backend (Eq. 3 via ``approx_rls``):

  * uniform          — [5]  (no scores; the fastest, highest-variance option)
  * two-pass         — [6]  El Alaoui & Mahoney
  * RECURSIVE-RLS    — [9]  Musco & Musco
  * SQUEAK           — [8]  Calandriello, Lazaric & Valko

Implementations follow the paper's unified notation (Sec. 2.2/2.3): each
method is a different schedule of ``L_J(U, lam) -> J'``. Every scoring round
goes through the kernel-operator ``Backend`` seam (resolved once per call,
then threaded through the rounds), so the baselines benchmark on the same
hardware path as BLESS.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .bless import _bucket, _multinomial, _pow2  # noqa: F401 — _pow2 re-exported
from .gram import BackendLike, Kernel, resolve_backend
from .leverage import CenterSet, approx_rls, uniform_center_set

Array = jax.Array


def uniform_centers(key: Array, n: int, m: int) -> CenterSet:
    """Uniform column sampling [5]; A = (M/n) I (see uniform_center_set)."""
    idx = jax.random.randint(key, (m,), 0, n)
    return uniform_center_set(idx, n, _bucket(m))


def _resample(key: Array, x: Array, u_idx: Array, u_mask: Array, centers: CenterSet,
              kernel: Kernel, lam: float, m_out: int, n: int, backend,
              scores: Array | None = None) -> CenterSet:
    """One leverage-score sampling round: L_{centers}(U, lam) -> J' (Eq. 5).

    ``scores`` short-circuits the Eq. 3 evaluation when the caller already
    scored exactly these candidates against these centers at this lam
    (RECURSIVE-RLS sizes m_out from the same scores it samples with).
    """
    if scores is None:
        scores = approx_rls(kernel, x[u_idx], u_mask, x, centers, jnp.asarray(lam),
                            backend=backend)
    s = jnp.where(u_mask, scores, 0.0)
    p = s / jnp.maximum(jnp.sum(s), 1e-30)
    r_h = int(jnp.sum(u_mask))
    mbuf = _bucket(m_out)
    pos = _multinomial(key, p, mbuf)
    j_mask = jnp.arange(mbuf) < m_out
    w = jnp.where(j_mask, (r_h * m_out / n) * p[pos], 1.0)
    return CenterSet(
        idx=u_idx[pos].astype(jnp.int32),
        weight=w.astype(jnp.float32),
        mask=j_mask,
        count=jnp.asarray(m_out, jnp.int32),
    )


def two_pass(key: Array, x: Array, kernel: Kernel, lam: float, *,
             m1: int | None = None, m2: int,
             backend: BackendLike = None) -> CenterSet:
    """Two-pass sampling [6]: uniform J1 (size ~1/lam), then L_{J1}([n], lam)."""
    n = x.shape[0]
    backend = resolve_backend(backend, n=n)
    m1 = m1 or min(n, int(math.ceil(kernel.kappa_sq / lam)))
    k1, k2 = jax.random.split(key)
    j1 = uniform_centers(k1, n, m1)
    u_idx = jnp.arange(_bucket(n), dtype=jnp.int32) % n
    u_mask = jnp.arange(_bucket(n)) < n
    return _resample(k2, x, u_idx, u_mask, j1, kernel, lam, m2, n, backend)


def recursive_rls(key: Array, x: Array, kernel: Kernel, lam: float, *,
                  q2: float = 2.0, depth: int | None = None,
                  m_cap: int | None = None,
                  backend: BackendLike = None) -> CenterSet:
    """RECURSIVE-RLS [9]: nested uniform U_1 c U_2 c ... c U_H = [n],
    |U_h| = n / 2^(H-h);  J_1 = U_1;  L_{J_h}(U_{h+1}, lam) -> J_{h+1}."""
    n = x.shape[0]
    backend = resolve_backend(backend, n=n)
    depth = depth or max(1, int(math.log2(max(2, n * lam))))
    perm = jax.random.permutation(key, n).astype(jnp.int32)
    sizes = [max(8, n // 2**(depth - h)) for h in range(depth)] + [n]
    j = uniform_center_set(perm[: sizes[0]], n, _bucket(sizes[0]))
    for h, r in enumerate(sizes[1:]):
        key, kh = jax.random.split(key)
        rbuf = _bucket(r)
        u_idx = perm[jnp.arange(rbuf) % n][: rbuf]
        u_mask = jnp.arange(rbuf) < r
        # m_out ~ q2 * estimated d_eff from current scores; the same scores
        # feed the sampling round below (one Eq. 3 evaluation per level, not
        # two — d_est and the draw see identical candidates/centers/lam)
        s = approx_rls(kernel, x[u_idx], u_mask, x, j, jnp.asarray(lam),
                       backend=backend)
        d_est = float(n / r * jnp.sum(jnp.where(u_mask, s, 0.0)))
        m_out = max(8, int(math.ceil(q2 * d_est)))
        if m_cap is not None:
            m_out = min(m_out, m_cap)
        j = _resample(kh, x, u_idx, u_mask, j, kernel, lam, m_out, n, backend,
                      scores=s)
    return j


def squeak(key: Array, x: Array, kernel: Kernel, lam: float, *,
           n_chunks: int | None = None, qbar: float = 2.0,
           m_cap: int | None = None, backend: BackendLike = None) -> CenterSet:
    """SQUEAK [8]: stream [n] in H chunks; merge-and-rescore
    L_{J_h u U_{h+1}}(J_h u U_{h+1}, lam) with Bernoulli thinning."""
    n = x.shape[0]
    backend = resolve_backend(backend, n=n)
    n_chunks = n_chunks or max(2, int(math.sqrt(max(4, n * lam))))
    perm = jax.random.permutation(key, n).astype(jnp.int32)
    chunk = n // n_chunks
    j_idx = perm[:chunk]
    j_w = jnp.full((chunk,), chunk / n, jnp.float32)
    for h in range(1, n_chunks):
        key, kh = jax.random.split(key)
        u_new = perm[h * chunk: (h + 1) * chunk]
        cand = jnp.concatenate([j_idx, u_new])
        cand_w = jnp.concatenate([j_w, jnp.full((u_new.shape[0],), (cand.shape[0]) / n, jnp.float32)])
        cbuf = _bucket(cand.shape[0])
        pad = cbuf - cand.shape[0]
        cs = CenterSet(
            idx=jnp.pad(cand, (0, pad)),
            weight=jnp.pad(cand_w, (0, pad), constant_values=1.0),
            mask=jnp.arange(cbuf) < cand.shape[0],
            count=jnp.asarray(cand.shape[0], jnp.int32),
        )
        s = approx_rls(kernel, x[cs.idx], cs.mask, x, cs, jnp.asarray(lam),
                       backend=backend)
        p = jnp.minimum(qbar * s, 1.0)
        keep = (jax.random.uniform(kh, (cbuf,)) < p) & cs.mask
        if m_cap is not None and int(jnp.sum(keep)) > m_cap:
            top = jnp.argsort(jnp.where(keep, -p, jnp.inf))[:m_cap]
            keep = jnp.zeros_like(keep).at[top].set(True) & keep
        sel = jnp.where(keep, jnp.arange(cbuf), cbuf)
        order = jnp.argsort(sel)[: int(jnp.sum(keep))]
        j_idx = cs.idx[order]
        j_w = p[order]  # importance weight: kept w.p. p -> A_jj = p_j
    mbuf = _bucket(j_idx.shape[0])
    pad = mbuf - j_idx.shape[0]
    return CenterSet(
        idx=jnp.pad(j_idx, (0, pad)),
        weight=jnp.pad(j_w, (0, pad), constant_values=1.0),
        mask=jnp.arange(mbuf) < j_idx.shape[0],
        count=jnp.asarray(j_idx.shape[0], jnp.int32),
    )
