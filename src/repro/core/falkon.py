"""FALKON with generalized (BLESS-weighted) preconditioner — paper Sec. 3 / App. B.

Solves Nystrom-KRR

    alpha = (K_nM^T K_nM + lam n K_MM)^+ K_nM^T y        (Eq. 13, lam*n conv.)

by conjugate gradient on the preconditioned system (Def. 3)

    W beta = b,   W = B^T (K_nM^T K_nM + lam n K_MM) B,  b = B^T K_nM^T y,

with the generalized preconditioner of Def. 2 / Eq. (15):

    B = (1/sqrt(n)) A^{-1/2} T^{-1} R^{-1},
    T = chol_u(A^{-1/2} K_MM A^{-1/2}),   R = chol_u(T T^T / M + lam I)

so that B B^T = (n/M K_MM A^{-1} K_MM + lam n K_MM)^{-1}.

The CG matvec never materializes K_nM: the K_nM^T K_nM v / K_nM^T y
contractions come from the kernel-operator ``Backend`` seam
(``repro.core.backend``) — the local pure-jnp streamer, the Pallas fused
kernel (repro.kernels.falkon_matvec), or the shard_map data-parallel one in
core/distributed.py. All three share this file's CG loop, and
``FalkonModel.predict`` serves K_nM alpha through the same seam.

Multi-RHS block-CG: ``y`` may be (n,) or (n, k). All k right-hand sides ride
ONE CG — the iterate is an (M, k) panel, every K_nM stream (the dominant
cost, identical for every column) is evaluated once per iteration and
contracted against the whole panel, and the preconditioner is shared. Each
column keeps its own step sizes (alpha_j, mu_j from per-column reductions)
with per-column convergence masking: a column whose residual has collapsed
to fp32 noise freezes while the others keep iterating. Extra output columns
therefore cost only the extra (n, k) GEMM flops, not extra kernel
evaluations — see ``cg`` and DESIGN.md §2.4.

Fused whole-fit path (DESIGN.md §2.4): for jit-safe backends with no
per-iteration callback, ``falkon_fit`` compiles preconditioner + CG + alpha
recovery into ONE ``jax.jit`` call — repeated fits (benchmark sweeps,
serving-side refits) pay a single dispatch instead of ~iters host round
trips. The jit cache is shape-bucketed: X/y rows are padded up to a multiple
of the backend's stream block and masked inside the trace, and the RHS
count k >= 2 is padded up to a power-of-two column bucket (zero columns are
frozen by the convergence mask; single-output keeps true vector shapes), so
every (n, k) in a bucket shares one executable. Cache key (static): row
bucket, k bucket, (M, d), iters,
backend instance, kernel family. Traced (never retraces): lam, n, X, y,
centers, a_diag, kernel bandwidth. The padded y panel is donated (it is
always freshly allocated here); X is NOT donated — callers reuse it across
fits (lambda sweeps, warm-start refits, k-fold sweeps).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from ..testing import faults
from . import health
from .gram import BackendLike, Kernel, resolve_backend
from .leverage import CenterSet  # noqa: F401 — re-exported for callers

Array = jax.Array


def _bcol(s: Array, v: Array) -> Array:
    """Broadcast a per-row scale (M,) against v of shape (M,) or (M, k)."""
    return s[:, None] if v.ndim == 2 else s


class Preconditioner(NamedTuple):
    """Factors of Def. 2, Example 1.3 (eigendecomposition branch).

    BLESS samples centers *with replacement*, so K_MM is routinely rank
    deficient (duplicate rows); the eigh-based partial isometry Q with rank
    truncation is the paper's own answer (Def. 2 requires only Q^T Q = I,
    q <= M) and is fp32-robust where the Cholesky branch explodes.

    ``apply``/``apply_t`` accept a single vector or an (·, k) panel — B is
    column-separable, so one application serves every CG right-hand side.
    """

    q_iso: Array  # (M, q) partial isometry
    t_diag: Array  # (q,)  T = diag(sqrt(eig))
    r_diag: Array  # (q,)  R = diag(sqrt(eig/M + lam))
    inv_sqrt_a: Array  # (M,) diag(A)^{-1/2}
    n: int

    def apply(self, v: Array) -> Array:
        """B v = (1/sqrt n) A^{-1/2} Q T^{-1} R^{-1} v,  v (q,) or (q, k)."""
        u = self.q_iso @ (v / _bcol(self.t_diag * self.r_diag, v))
        return _bcol(self.inv_sqrt_a, u) * u / jnp.sqrt(self.n)

    def apply_t(self, v: Array) -> Array:
        """B^T v,  v (M,) or (M, k) -> (q,) or (q, k)."""
        u = self.q_iso.T @ (_bcol(self.inv_sqrt_a, v) * v / jnp.sqrt(self.n))
        return u / _bcol(self.t_diag * self.r_diag, u)


def make_preconditioner(kernel: Kernel, z: Array, a_diag: Array, lam: float, n: int,
                        *, rank_tol: float = 1e-5) -> Preconditioner:
    """Def. 2 factors for centers z (M, d) with weights diag(A) = a_diag.

    eigh of A^{-1/2} K_MM A^{-1/2}; eigenvalues below rank_tol * max are
    dropped (q = numerical rank), exactly Example 1.3 with q = rank(K_MM).
    """
    m = z.shape[0]
    kmm = kernel.cross(z, z).astype(jnp.float32)
    inv_sqrt_a = (1.0 / jnp.sqrt(a_diag)).astype(jnp.float32)
    kt = kmm * (inv_sqrt_a[:, None] * inv_sqrt_a[None, :])
    eig, vec = jnp.linalg.eigh(kt)
    floor = jnp.maximum(eig[-1], 1e-30) * rank_tol
    keep = eig > floor
    # jit-friendly fixed shapes: keep all M columns but neutralize dropped
    # directions (T entry -> 1, Q column -> 0): B then annihilates them.
    t_diag = jnp.sqrt(jnp.where(keep, eig, 1.0))
    r_diag = jnp.sqrt(jnp.where(keep, eig / m + lam, 1.0))
    q_iso = vec * keep[None, :].astype(vec.dtype)
    return Preconditioner(q_iso, t_diag, r_diag, inv_sqrt_a, n)


# ---------------------------------------------------------------------------
# K_nM operators
# ---------------------------------------------------------------------------

KnmOp = Callable[[Array], tuple[Array, Array]]
# v (M,) or (M, k) -> (K_nM^T K_nM v, K_nM^T y)  -- the second returned once


def local_knm_quadratic(kernel: Kernel, x: Array, z: Array, *, block: int = 8192,
                        mask: Array | None = None) -> Callable[[Array], Array]:
    """v -> K_nM^T (K_nM v), streaming x in row blocks (pure-jnp reference).

    ``v`` may be (M,) or an (M, k) panel: each streamed Gram block is built
    once and contracted against every column, so extra right-hand sides cost
    GEMM flops only — no extra kernel evaluations.

    ``mask`` — optional per-row weights excluding rows from the quadratic
    form: (n,) applied to every column, or an (n, k) panel giving column j
    its own row subset (exact row-exclusion CV; DESIGN.md §2.4). Column j
    then computes ``K_nM^T diag(mask[:, j]) K_nM v_j`` — one extra
    elementwise multiply on the streamed (block, k) intermediate, applied
    *between* the two Gram contractions so binary masks count excluded rows
    exactly once. ``mask=None`` keeps the original program bit-identical.
    """
    n, m = x.shape[0], z.shape[0]
    pad = (-n) % block
    xp = jnp.pad(x, ((0, pad), (0, 0)))
    nb = xp.shape[0] // block
    valid = (jnp.arange(nb * block) < n).reshape(nb, block)
    if mask is not None:
        mk = jnp.pad(jnp.asarray(mask, x.dtype),
                     ((0, pad),) + ((0, 0),) * (mask.ndim - 1))
        mk = mk.reshape((nb, block) + mk.shape[1:])

    def op(v: Array) -> Array:
        def body(carry, args):
            xb, mb, cb = args
            g = kernel.cross(xb, z) * mb[:, None]
            t = g @ v
            if cb is not None:
                t = t * (cb if t.ndim == cb.ndim else cb[:, None])
            return carry + g.T @ t, None

        out, _ = jax.lax.scan(body, jnp.zeros((m,) + v.shape[1:], v.dtype),
                              (xp.reshape(nb, block, -1), valid,
                               None if mask is None else mk))
        return out

    return op


def local_knm_t(kernel: Kernel, x: Array, z: Array, y: Array, *, block: int = 8192,
                mask: Array | None = None) -> Array:
    """K_nM^T y, streamed; ``y`` (n,) -> (M,), or an (n, k) panel -> (M, k).

    ``mask`` — optional per-row weights, (n,) or (n, k) matching ``y``:
    computes ``K_nM^T (mask * y)``. Since the mask enters linearly it is
    folded into the targets up front (one elementwise multiply); the
    streamed program is otherwise unchanged.
    """
    if mask is not None:
        y = y * jnp.asarray(mask, y.dtype)
    n, m = x.shape[0], z.shape[0]
    pad = (-n) % block
    xp = jnp.pad(x, ((0, pad), (0, 0)))
    yp = jnp.pad(y, ((0, pad),) + ((0, 0),) * (y.ndim - 1))
    nb = xp.shape[0] // block

    def body(carry, args):
        xb, yb = args
        return carry + kernel.cross(xb, z).T @ yb, None

    out, _ = jax.lax.scan(body, jnp.zeros((m,) + y.shape[1:], x.dtype),
                          (xp.reshape(nb, block, -1),
                           yp.reshape((nb, block) + y.shape[1:])))
    return out


# ---------------------------------------------------------------------------
# Conjugate gradient
# ---------------------------------------------------------------------------


#: Per-column freeze threshold: a column whose squared residual norm has
#: dropped below this fraction of its initial value (or started at exactly
#: zero — padded bucket columns) is converged to fp32 noise; freezing it
#: avoids 0/0 step sizes and needless panel updates while other columns
#: keep iterating. sqrt(1e-14) ~ fp32 eps, so no legitimate progress is cut.
_CG_FREEZE_REL = 1e-14


def cg(matvec: Callable[[Array], Array], b: Array, iters: int,
       callback: Callable[[int, Array], None] | None = None,
       trajectory: bool = False) -> Array | tuple[Array, Array]:
    """CG on SPD ``matvec``; fixed iteration count (paper uses t ~ log n).

    ``b`` may be a single right-hand side (q,) or an (q, k) panel — the
    multi-RHS block-CG form: one ``matvec`` evaluation per iteration serves
    every column (the operator cost is column-count independent up to GEMM
    flops), while the scalar recurrences (alpha, mu) run per column from
    axis-0 reductions. Columns are individually frozen once converged (see
    ``_CG_FREEZE_REL``); for (q,) inputs the recurrence is exactly plain CG.

    With ``trajectory=True`` returns ``(beta, residuals)`` where
    ``residuals`` is the (iters+1,) — or (iters+1, k) — squared residual
    norm history (row 0 = initial): the raw material for the §9 health
    diagnostics (``health.SolveDiagnostics``). The history is a small
    carried array updated in place; its cost is invisible next to the
    K_nM-streaming matvec. (Frozen columns stop updating r, so their
    recorded residual simply plateaus — the recorded value stays exact.)

    With ``callback`` the loop runs on host (per-iteration metrics for the
    Fig. 4/5 analogues); otherwise it is a single jitted lax.fori_loop.
    """
    rs0 = jnp.sum(b * b, axis=0)

    def step(state):
        beta, r, p, rs = state
        ap = matvec(p)
        active = rs > _CG_FREEZE_REL * rs0
        alpha = jnp.where(active,
                          rs / jnp.maximum(jnp.sum(p * ap, axis=0), 1e-30), 0.0)
        beta = beta + alpha * p
        r = r - alpha * ap
        rs_new = jnp.sum(r * r, axis=0)
        mu = jnp.where(active, rs_new / jnp.maximum(rs, 1e-30), 0.0)
        p = jnp.where(active, r + mu * p, p)
        return beta, r, p, jnp.where(active, rs_new, rs)

    state = (jnp.zeros_like(b), b, b, rs0)
    if callback is not None:
        resid = [rs0]
        for i in range(iters):
            state = step(state)
            resid.append(state[3])
            callback(i, state[0])
        if trajectory:
            return state[0], jnp.stack(resid)
        return state[0]
    if trajectory:
        traj0 = jnp.zeros((iters + 1,) + rs0.shape, rs0.dtype).at[0].set(rs0)

        def tstep(i, st):
            inner, traj = st
            inner = step(inner)
            return inner, traj.at[i + 1].set(inner[3])

        (beta, *_), traj = jax.lax.fori_loop(0, iters, tstep, (state, traj0))
        return beta, traj
    return jax.lax.fori_loop(0, iters, lambda _, s: step(s), state)[0]


# ---------------------------------------------------------------------------
# Fused whole-fit path (see module docstring / DESIGN.md §2.4)
# ---------------------------------------------------------------------------

#: times _fused_falkon_solve was traced (i.e. compiled for a new shape
#: bucket). Tests assert a second same-bucket fit does NOT bump this — the
#: whole solve is then a single cached compiled call with zero host-side CG
#: dispatches.
_FUSED_FIT_TRACES = 0


def _fit_block(backend) -> int:
    """Stream-block (and row-bucket granularity) for a jit-safe backend."""
    get = getattr(backend, "_block", None)
    return get() if get is not None else 4096


def _k_bucket(k: int) -> int:
    """Column bucket for the fused-fit cache: next power of two >= k.

    One compiled solve serves every RHS count in a bucket (k is padded with
    zero columns that the per-column convergence mask freezes from iteration
    zero), bounding the jit cache at log2(k_max) executables per row bucket.
    """
    return 1 << max(0, k - 1).bit_length()


def _masked_knm_ops(kernel: Kernel, xp: Array, z: Array, yp: Array,
                    row_mask: Array, block: int,
                    col_mask: Array | None = None):
    """(quadratic op, K_nM^T y) over bucket-padded rows with a traced
    validity mask — same math as local_knm_quadratic / local_knm_t, but the
    mask is a tracer so one compiled solve serves every n in the bucket.
    ``yp`` is (n_pad,) for a single-output fit or an (n_pad, kb) panel for
    multi-RHS; the quadratic op consumes matching (M,) / (M, kb) iterates.
    (True vector shapes are kept for kb absent — an (n, 1) panel lowers to
    a markedly slower CPU program than the equivalent matvec.)

    ``col_mask`` — optional per-column row-exclusion weights shaped like
    ``yp``: column j of the quadratic form sees only its masked rows (exact
    k-fold CV), applied as one extra elementwise multiply on the streamed
    (block, kb) intermediate. Padding rows must already be zeroed by the
    caller (falkon_fit pads with zeros). When None, the program is the
    pre-mask one bit-for-bit — a different pytree structure, so masked and
    unmasked fits compile to separate cache entries and the unmasked hot
    path keeps its exact pre-CV executable."""
    m = z.shape[0]
    nb = xp.shape[0] // block
    xb = xp.reshape(nb, block, xp.shape[1])
    mb = row_mask.reshape(nb, block).astype(xp.dtype)
    cmb = (None if col_mask is None
           else col_mask.reshape((nb, block) + yp.shape[1:]))

    def quad(v: Array) -> Array:
        def body(carry, args):
            xblk, mblk, cblk = args
            g = kernel.cross(xblk, z) * mblk[:, None]
            t = g @ v
            if cblk is not None:
                t = t * cblk
            return carry + g.T @ t, None

        out, _ = jax.lax.scan(body, jnp.zeros((m,) + v.shape[1:], v.dtype),
                              (xb, mb, cmb))
        return out

    def body_t(carry, args):
        xblk, yblk = args
        return carry + kernel.cross(xblk, z).T @ yblk, None

    ym = yp * (row_mask if yp.ndim == 1 else row_mask[:, None])
    if col_mask is not None:
        ym = ym * col_mask
    kty, _ = jax.lax.scan(body_t, jnp.zeros((m,) + yp.shape[1:], xp.dtype),
                          (xb, ym.reshape((nb, block) + yp.shape[1:])))
    return quad, kty


@partial(jax.jit, static_argnames=("iters", "backend", "block"),
         donate_argnames=("yp",))
def _fused_falkon_solve(kernel: Kernel, xp: Array, yp: Array, centers: Array,
                        a_diag: Array, lam: Array, n: Array, *, iters: int,
                        backend, block: int,
                        col_mask: Array | None = None) -> tuple[Array, Array]:
    """Preconditioner + multi-RHS CG + alpha recovery as one compiled program.

    ``yp`` is the bucket-padded target: (n_pad,) for single-output, or an
    (n_pad, kb) panel for multi-RHS; alpha comes back with matching shape
    and the caller slices the real columns out. Also returns the CG
    residual trajectory for the §9 health diagnostics.

    ``col_mask`` (optional, shaped like ``yp``, zero-padded) gives every
    column its own row subset: column j solves
    ``(K_nM^T diag(m_j) K_nM + lam n_j K_MM) alpha_j = K_nM^T (m_j * y_j)``
    with n_j = sum(m_j) — the per-fold normal equations of exact
    row-exclusion CV. The preconditioner keeps the *global* n: B enters CG
    only as a symmetric congruence, and CG iterates are exactly invariant
    under the (c^2 A, c b) rescaling that a per-column 1/sqrt(n_j) would
    introduce, so the shared factorization changes nothing (DESIGN.md §2.4).
    """
    global _FUSED_FIT_TRACES
    _FUSED_FIT_TRACES += 1
    row_mask = jnp.arange(xp.shape[0]) < n
    prec = make_preconditioner(kernel, centers, a_diag, lam, n)
    kmm = backend.gram_block(kernel, centers, centers)
    quad, kty = _masked_knm_ops(kernel, xp, centers, yp, row_mask, block,
                                col_mask)
    # Per-column effective row count for the lam * n_j * K_MM term; scalar n
    # (the original program) when no mask is given.
    n_eff = n if col_mask is None else jnp.sum(col_mask, axis=0)

    def matvec(v: Array) -> Array:
        u = prec.apply(v)
        w = quad(u) + lam * n_eff * (kmm @ u)
        return prec.apply_t(w)

    beta, resid = cg(matvec, prec.apply_t(kty), iters, trajectory=True)
    return prec.apply(beta), resid


# ---------------------------------------------------------------------------
# FALKON estimator
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class FalkonModel:
    """A fitted FALKON / Nystrom-KRR predictor: x -> K(x, centers) alpha."""

    centers: Array  # (M, d)
    alpha: Array  # (M,) or (M, k) for multi-output fits
    kernel: Kernel
    #: serving-time contraction backend; set by falkon_fit to the fit-time
    #: choice, overridable per predict call. None -> platform heuristic.
    backend: BackendLike = None
    #: §9 solver health report (CG residual trajectory with lazy
    #: converged/stalled/diverged classification); None for models built
    #: by the direct solvers or hand-assembled.
    diagnostics: "health.SolveDiagnostics | None" = None
    #: fit-time regularization / row count / center weights, recorded by the
    #: solvers so ``predictive_variance`` can rebuild the posterior operator
    #: (K_MM + lam n A); None on hand-assembled models (variance raises).
    lam: float | None = None
    n_train: int | None = None
    a_diag: Array | None = None

    def predictive_variance(self, x: Array, *, backend: BackendLike = None) -> Array:
        """GP-style Nystrom posterior variance per row of ``x``.

        Computes ``k(x, x) - k_xM (K_MM + lam n A)^{-1} k_Mx`` — the
        predictive variance of the degenerate-GP reading of Nystrom-KRR
        (weights A from the sampler; A = I for uniform/exact fits). This is
        exactly ``lam * n`` times the ridge leverage score of x against the
        centers, so it rides the seam's fused ``rls_scores`` path: the
        Pallas backend takes the one-kernel RLS program, ``StreamBackend``
        streams x in host chunks with the (M, M) factorization hoisted out
        of the loop — out-of-core n works unchanged.

        Returns (n,) nonnegative variances (clipped at 0 against fp32
        cancellation; multi-output models share one variance — it does not
        depend on y). Raises ``ValueError`` on models missing the fit
        metadata (lam / n_train), e.g. hand-assembled ones.
        """
        if self.lam is None or self.n_train is None:
            raise ValueError(
                "predictive_variance needs fit metadata (lam, n_train); this "
                "model was built without it — refit via falkon_fit / "
                "nystrom_krr / exact_krr")
        spec = backend if backend is not None else self.backend
        be = resolve_backend(spec, n=x.shape[0])
        m = self.centers.shape[0]
        a = (jnp.ones((m,), jnp.float32) if self.a_diag is None
             else self.a_diag.astype(jnp.float32))
        lam_n = jnp.asarray(self.lam * self.n_train, jnp.float32)
        scores = be.rls_scores(self.kernel, x, self.centers,
                               jnp.ones((m,), bool), lam_n * a, lam_n)
        return jnp.maximum(lam_n * scores, 0.0)

    def predict(self, x: Array, *, backend: BackendLike = None) -> Array:
        """K(x, centers) alpha through the kernel-operator seam.

        Returns (n,) for a single-output model, (n, k) for a multi-output
        one. Both take the fused ``knm_matvec`` panel contraction: K_nM is
        never materialized, each streamed Gram block is evaluated once and
        contracted against every alpha column, so extra outputs cost GEMM
        flops only.

        This is the serving dispatch boundary, so it hosts the chaos
        harness's dispatch-level injection points (inert one-dict-check
        when nothing is armed; see repro/testing/faults.py). It carries no
        finite fence of its own — the serving engines fence per wave where
        the result is materialized anyway, and raw callers opt in via
        ``health.check_finite``.
        """
        spec = backend if backend is not None else self.backend
        be = resolve_backend(spec, n=x.shape[0])
        if faults.active():
            faults.sleep_if(rows=x.shape[0], centers=self.centers.shape[0])
            faults.raise_if()
        out = be.knm_matvec(self.kernel, x, self.centers, self.alpha)
        if faults.active():
            out = faults.corrupt("gram.nan_tile", out)
        return out


def falkon_fit(
    kernel: Kernel,
    x: Array,
    y: Array,
    centers: Array,
    lam: float,
    *,
    a_diag: Array | None = None,
    iters: int = 20,
    backend: BackendLike = None,
    callback: Callable[[int, FalkonModel], None] | None = None,
    fused: bool | None = None,
    check_finite: bool = False,
    row_mask: Array | None = None,
) -> FalkonModel:
    """Fit FALKON (uniform A=I) or FALKON-BLESS (A from Alg. 1/2).

    ``backend`` selects the K_nM operator implementation — an instance, a
    registry name ("jnp" | "pallas" | "sharded"), or None for the platform
    heuristic (repro.core.backend.default_backend).

    ``fused`` selects the whole-fit compilation path (see module docstring):
    None (default) takes it automatically when the backend is jit-safe and no
    ``callback`` needs the host CG loop; True forces it (raising if the
    backend cannot be traced); False forces the host-driven path.

    ``y`` may be (n,) or (n, k): multi-output targets ride ONE multi-RHS
    block-CG against the same centers — the preconditioner, the K_nM
    streaming and (on jit-safe backends) the fused-fit compile are all
    shared across columns, so extra outputs cost only the extra GEMM flops.
    On the fused path k is padded up to a power-of-two column bucket
    (``_k_bucket``) so every RHS count in a bucket shares one executable.

    Every fit records its CG residual trajectory as
    ``model.diagnostics`` (``health.SolveDiagnostics`` — lazy, no device
    sync until a property is read). ``check_finite=True`` arms the §9
    output fence: raise ``health.NonFiniteError`` instead of returning a
    NaN alpha. It defaults off because the check is one blocking device
    round-trip per fit — real cost in the hot sweep paths (fig3 warm-start
    refits, KFoldSweep grids) that dispatch many fits back to back.

    ``row_mask`` — optional per-column row-exclusion weights shaped like
    ``y`` ((n,) or (n, k)): column j is fit on only its masked rows, i.e.
    solves ``(K_nM^T diag(m_j) K_nM + lam n_j K_MM) alpha_j =
    K_nM^T (m_j y_j)`` with n_j = sum(m_j). This is the exact k-fold CV
    mechanism (every fold = one masked RHS column of a single multi-RHS
    solve); the shared preconditioner keeps the global n, which is exact —
    CG iterates are invariant under the per-column rescaling (see
    ``_fused_falkon_solve``). ``row_mask=None`` keeps the pre-mask program
    (and its jit cache entries) bit-for-bit.
    """
    n = x.shape[0]
    m = centers.shape[0]
    backend = resolve_backend(backend, n=n)
    single = y.ndim == 1
    if not single and callback is not None:
        raise ValueError("per-iteration callback is single-output only; "
                         "fit columns separately to trace them")
    if row_mask is not None:
        row_mask = jnp.asarray(row_mask, x.dtype)
        if row_mask.shape != y.shape:
            raise ValueError(f"row_mask shape {row_mask.shape} must match "
                             f"y shape {y.shape}")
    a_diag = jnp.ones((m,), x.dtype) if a_diag is None else a_diag
    if fused is None:
        fused = backend.jit_safe and callback is None
    if fused:
        if not backend.jit_safe:
            raise ValueError(f"fused=True needs a jit-safe backend, got {backend.name!r}")
        if callback is not None:
            raise ValueError("the fused fit has no host CG loop; "
                             "pass fused=False to use callback")
        block = _fit_block(backend)
        pad = (-n) % block
        # Single-output keeps true vector shapes (an (n, 1) panel lowers to
        # a much slower CPU program); k >= 2 pads to the pow2 column bucket.
        col_pad = 0 if single else _k_bucket(y.shape[1]) - y.shape[1]
        # yp is donated by _fused_falkon_solve, so it must be a fresh buffer
        # even when the bucket needs no padding (x is shared, never donated).
        if pad or col_pad:
            yp = jnp.pad(y, ((0, pad),) if single else ((0, pad), (0, col_pad)))
        else:
            yp = y + jnp.zeros((), y.dtype)
        col_mask = None
        if row_mask is not None:
            # Zero-pad like yp: padded rows drop out of the quadratic form
            # and padded columns get n_j = 0 (frozen by the CG mask anyway).
            col_mask = jnp.pad(
                row_mask,
                ((0, pad),) if single else ((0, pad), (0, col_pad)))
        alpha, resid = _fused_falkon_solve(
            kernel, jnp.pad(x, ((0, pad), (0, 0))), yp, centers, a_diag,
            jnp.asarray(lam, jnp.float32), jnp.asarray(n, jnp.int32),
            iters=iters, backend=backend, block=block, col_mask=col_mask)
        alpha = alpha if single else alpha[:, : y.shape[1]]
        resid = resid if single else resid[:, : y.shape[1]]
        if check_finite:
            health.check_finite(alpha, "falkon_fit alpha (fused)")
        return FalkonModel(centers=centers, alpha=alpha, kernel=kernel,
                           backend=backend,
                           diagnostics=health.SolveDiagnostics(resid),
                           lam=float(lam), n_train=n, a_diag=a_diag)
    prec = make_preconditioner(kernel, centers, a_diag, lam, n)
    kmm = backend.gram_block(kernel, centers, centers)
    quad, kty = backend.knm_operators(kernel, x, centers, y, mask=row_mask)
    n_eff = n if row_mask is None else jnp.sum(row_mask, axis=0)

    def matvec(v: Array) -> Array:
        u = prec.apply(v)
        w = quad(u) + lam * n_eff * (kmm @ u)
        return prec.apply_t(w)

    b = prec.apply_t(kty)
    cb = None
    if callback is not None:
        def cb(i, beta):  # noqa: E731 — host-side metric hook
            callback(i, FalkonModel(centers=centers, alpha=prec.apply(beta),
                                    kernel=kernel, backend=backend))
    beta, resid = cg(matvec, b, iters, callback=cb, trajectory=True)
    alpha = prec.apply(beta)
    if check_finite:
        health.check_finite(alpha, "falkon_fit alpha")
    return FalkonModel(centers=centers, alpha=alpha, kernel=kernel,
                       backend=backend,
                       diagnostics=health.SolveDiagnostics(resid),
                       lam=float(lam), n_train=n, a_diag=a_diag)


def falkon_bless_fit(key: Array, kernel: Kernel, x: Array, y: Array, lam_bless: float,
                     lam_falkon: float, *, iters: int = 20, q2: float = 3.0,
                     m_cap: int | None = None, backend: BackendLike = None,
                     callback=None) -> FalkonModel:
    """FALKON-BLESS end-to-end (the paper's lam_bless >> lam_falkon trick,
    Sec. 4). Thin shim over the ``repro.api`` front door — equivalent to
    ``FalkonRegressor(sampler=BlessSampler(lam=lam_bless, ...))`` — kept for
    source compatibility; tests/test_api.py proves the paths bit-identical.

    The upward delegation is deliberate: the sampler+solver *composition*
    has exactly one implementation (the estimator), so shim and front door
    cannot drift. The import is lazy/call-time, keeping module import order
    acyclic (api imports core at module scope, never the reverse).
    """
    from ..api.estimators import FalkonRegressor, FitConfig  # api sits above core
    from ..api.samplers import BlessSampler

    est = FalkonRegressor(
        kernel=kernel,
        sampler=BlessSampler(lam=lam_bless, q2=q2, m_cap=m_cap),
        config=FitConfig(lam=lam_falkon, iters=iters, backend=backend),
    )
    return est.fit(x, y, key=key, callback=callback).model_
