"""FALKON with generalized (BLESS-weighted) preconditioner — paper Sec. 3 / App. B.

Solves Nystrom-KRR

    alpha = (K_nM^T K_nM + lam n K_MM)^+ K_nM^T y        (Eq. 13, lam*n conv.)

by conjugate gradient on the preconditioned system (Def. 3)

    W beta = b,   W = B^T (K_nM^T K_nM + lam n K_MM) B,  b = B^T K_nM^T y,

with the generalized preconditioner of Def. 2 / Eq. (15):

    B = (1/sqrt(n)) A^{-1/2} T^{-1} R^{-1},
    T = chol_u(A^{-1/2} K_MM A^{-1/2}),   R = chol_u(T T^T / M + lam I)

so that B B^T = (n/M K_MM A^{-1} K_MM + lam n K_MM)^{-1}.

The CG matvec never materializes K_nM: the K_nM^T K_nM v / K_nM^T y
contractions come from the kernel-operator ``Backend`` seam
(``repro.core.backend``) — the local pure-jnp streamer, the Pallas fused
kernel (repro.kernels.falkon_matvec), or the shard_map data-parallel one in
core/distributed.py. All three share this file's CG loop, and
``FalkonModel.predict`` serves K_nM alpha through the same seam.

Fused whole-fit path (DESIGN.md §2.4): for jit-safe backends with no
per-iteration callback, ``falkon_fit`` compiles preconditioner + CG + alpha
recovery into ONE ``jax.jit`` call — repeated fits (benchmark sweeps,
serving-side refits) pay a single dispatch instead of ~iters host round
trips. The jit cache is shape-bucketed: X/y rows are padded up to a multiple
of the backend's stream block and masked inside the trace, so every n in a
bucket shares one executable. Cache key (static): row bucket, (M, d), iters,
backend instance, kernel family. Traced (never retraces): lam, n, X, y,
centers, a_diag, kernel bandwidth. The padded y buffer is donated (it is
always freshly allocated here); X is not (callers reuse it across fits).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from .gram import BackendLike, Kernel, resolve_backend
from .leverage import CenterSet, _chol_with_jitter

Array = jax.Array


class Preconditioner(NamedTuple):
    """Factors of Def. 2, Example 1.3 (eigendecomposition branch).

    BLESS samples centers *with replacement*, so K_MM is routinely rank
    deficient (duplicate rows); the eigh-based partial isometry Q with rank
    truncation is the paper's own answer (Def. 2 requires only Q^T Q = I,
    q <= M) and is fp32-robust where the Cholesky branch explodes.
    """

    q_iso: Array  # (M, q) partial isometry
    t_diag: Array  # (q,)  T = diag(sqrt(eig))
    r_diag: Array  # (q,)  R = diag(sqrt(eig/M + lam))
    inv_sqrt_a: Array  # (M,) diag(A)^{-1/2}
    n: int

    def apply(self, v: Array) -> Array:
        """B v = (1/sqrt n) A^{-1/2} Q T^{-1} R^{-1} v,  v (q,)."""
        u = self.q_iso @ (v / (self.t_diag * self.r_diag))
        return self.inv_sqrt_a * u / jnp.sqrt(self.n)

    def apply_t(self, v: Array) -> Array:
        """B^T v,  v (M,) -> (q,)."""
        u = self.q_iso.T @ (self.inv_sqrt_a * v / jnp.sqrt(self.n))
        return u / (self.t_diag * self.r_diag)


def make_preconditioner(kernel: Kernel, z: Array, a_diag: Array, lam: float, n: int,
                        *, rank_tol: float = 1e-5) -> Preconditioner:
    """Def. 2 factors for centers z (M, d) with weights diag(A) = a_diag.

    eigh of A^{-1/2} K_MM A^{-1/2}; eigenvalues below rank_tol * max are
    dropped (q = numerical rank), exactly Example 1.3 with q = rank(K_MM).
    """
    m = z.shape[0]
    kmm = kernel.cross(z, z).astype(jnp.float32)
    inv_sqrt_a = (1.0 / jnp.sqrt(a_diag)).astype(jnp.float32)
    kt = kmm * (inv_sqrt_a[:, None] * inv_sqrt_a[None, :])
    eig, vec = jnp.linalg.eigh(kt)
    floor = jnp.maximum(eig[-1], 1e-30) * rank_tol
    keep = eig > floor
    # jit-friendly fixed shapes: keep all M columns but neutralize dropped
    # directions (T entry -> 1, Q column -> 0): B then annihilates them.
    t_diag = jnp.sqrt(jnp.where(keep, eig, 1.0))
    r_diag = jnp.sqrt(jnp.where(keep, eig / m + lam, 1.0))
    q_iso = vec * keep[None, :].astype(vec.dtype)
    return Preconditioner(q_iso, t_diag, r_diag, inv_sqrt_a, n)


# ---------------------------------------------------------------------------
# K_nM operators
# ---------------------------------------------------------------------------

KnmOp = Callable[[Array], tuple[Array, Array]]
# v (M,) -> (K_nM^T K_nM v  (M,),  K_nM^T y (M,))  -- the second returned once


def local_knm_quadratic(kernel: Kernel, x: Array, z: Array, *, block: int = 8192) -> Callable[[Array], Array]:
    """v -> K_nM^T (K_nM v), streaming x in row blocks (pure-jnp reference)."""
    n, m = x.shape[0], z.shape[0]
    pad = (-n) % block
    xp = jnp.pad(x, ((0, pad), (0, 0)))
    nb = xp.shape[0] // block
    valid = (jnp.arange(nb * block) < n).reshape(nb, block)

    def op(v: Array) -> Array:
        def body(carry, args):
            xb, mb = args
            g = kernel.cross(xb, z) * mb[:, None]
            return carry + g.T @ (g @ v), None

        out, _ = jax.lax.scan(body, jnp.zeros((m,), v.dtype),
                              (xp.reshape(nb, block, -1), valid))
        return out

    return op


def local_knm_t(kernel: Kernel, x: Array, z: Array, y: Array, *, block: int = 8192) -> Array:
    """K_nM^T y, streamed."""
    n, m = x.shape[0], z.shape[0]
    pad = (-n) % block
    xp = jnp.pad(x, ((0, pad), (0, 0)))
    yp = jnp.pad(y, (0, pad))
    nb = xp.shape[0] // block

    def body(carry, args):
        xb, yb = args
        return carry + kernel.cross(xb, z).T @ yb, None

    out, _ = jax.lax.scan(body, jnp.zeros((m,), x.dtype),
                          (xp.reshape(nb, block, -1), yp.reshape(nb, block)))
    return out


# ---------------------------------------------------------------------------
# Conjugate gradient
# ---------------------------------------------------------------------------


def cg(matvec: Callable[[Array], Array], b: Array, iters: int,
       callback: Callable[[int, Array], None] | None = None) -> Array:
    """Plain CG on SPD ``matvec``; fixed iteration count (paper uses t ~ log n).

    With ``callback`` the loop runs on host (per-iteration metrics for the
    Fig. 4/5 analogues); otherwise it is a single jitted lax.fori_loop.
    """
    if callback is not None:
        beta = jnp.zeros_like(b)
        r = b
        p = r
        rs = jnp.dot(r, r)
        for i in range(iters):
            ap = matvec(p)
            alpha = rs / jnp.maximum(jnp.dot(p, ap), 1e-30)
            beta = beta + alpha * p
            r = r - alpha * ap
            rs_new = jnp.dot(r, r)
            p = r + (rs_new / jnp.maximum(rs, 1e-30)) * p
            rs = rs_new
            callback(i, beta)
        return beta

    def body(_, state):
        beta, r, p, rs = state
        ap = matvec(p)
        alpha = rs / jnp.maximum(jnp.dot(p, ap), 1e-30)
        beta = beta + alpha * p
        r = r - alpha * ap
        rs_new = jnp.dot(r, r)
        p = r + (rs_new / jnp.maximum(rs, 1e-30)) * p
        return beta, r, p, rs_new

    init = (jnp.zeros_like(b), b, b, jnp.dot(b, b))
    return jax.lax.fori_loop(0, iters, body, init)[0]


# ---------------------------------------------------------------------------
# Fused whole-fit path (see module docstring / DESIGN.md §2.4)
# ---------------------------------------------------------------------------

#: times _fused_falkon_solve was traced (i.e. compiled for a new shape
#: bucket). Tests assert a second same-bucket fit does NOT bump this — the
#: whole solve is then a single cached compiled call with zero host-side CG
#: dispatches.
_FUSED_FIT_TRACES = 0


def _fit_block(backend) -> int:
    """Stream-block (and row-bucket granularity) for a jit-safe backend."""
    get = getattr(backend, "_block", None)
    return get() if get is not None else 4096


def _masked_knm_ops(kernel: Kernel, xp: Array, z: Array, yp: Array,
                    row_mask: Array, block: int):
    """(quadratic op, K_nM^T y) over bucket-padded rows with a traced
    validity mask — same math as local_knm_quadratic / local_knm_t, but the
    mask is a tracer so one compiled solve serves every n in the bucket."""
    m = z.shape[0]
    nb = xp.shape[0] // block
    xb = xp.reshape(nb, block, xp.shape[1])
    mb = row_mask.reshape(nb, block).astype(xp.dtype)

    def quad(v: Array) -> Array:
        def body(carry, args):
            xblk, mblk = args
            g = kernel.cross(xblk, z) * mblk[:, None]
            return carry + g.T @ (g @ v), None

        out, _ = jax.lax.scan(body, jnp.zeros((m,), v.dtype), (xb, mb))
        return out

    def body_t(carry, args):
        xblk, yblk = args
        return carry + kernel.cross(xblk, z).T @ yblk, None

    kty, _ = jax.lax.scan(body_t, jnp.zeros((m,), xp.dtype),
                          (xb, (yp * row_mask).reshape(nb, block)))
    return quad, kty


@partial(jax.jit, static_argnames=("iters", "backend", "block"),
         donate_argnames=("yp",))
def _fused_falkon_solve(kernel: Kernel, xp: Array, yp: Array, centers: Array,
                        a_diag: Array, lam: Array, n: Array, *, iters: int,
                        backend, block: int) -> Array:
    """Preconditioner + CG + alpha recovery as one compiled program."""
    global _FUSED_FIT_TRACES
    _FUSED_FIT_TRACES += 1
    row_mask = jnp.arange(xp.shape[0]) < n
    prec = make_preconditioner(kernel, centers, a_diag, lam, n)
    kmm = backend.gram_block(kernel, centers, centers)
    quad, kty = _masked_knm_ops(kernel, xp, centers, yp, row_mask, block)

    def matvec(v: Array) -> Array:
        u = prec.apply(v)
        w = quad(u) + lam * n * (kmm @ u)
        return prec.apply_t(w)

    beta = cg(matvec, prec.apply_t(kty), iters)
    return prec.apply(beta)


# ---------------------------------------------------------------------------
# FALKON estimator
# ---------------------------------------------------------------------------

#: Multi-output predict materializes the (n, M) Gram block only below this
#: element count (16M fp32 = 64 MB); larger batches stream per column.
_PREDICT_GRAM_ELEMS = 1 << 24


@dataclasses.dataclass(frozen=True)
class FalkonModel:
    centers: Array  # (M, d)
    alpha: Array  # (M,) or (M, k) for multi-output fits
    kernel: Kernel
    #: serving-time contraction backend; set by falkon_fit to the fit-time
    #: choice, overridable per predict call. None -> platform heuristic.
    backend: BackendLike = None

    def predict(self, x: Array, *, backend: BackendLike = None) -> Array:
        """K(x, centers) alpha through the kernel-operator seam.

        Returns (n,) for a single-output model, (n, k) for a multi-output
        one. Single-output takes the fused ``knm_matvec`` (K_nM never
        materialized). Multi-output pays one kernel evaluation regardless of
        k when the (n, M) Gram block fits a bounded intermediate (one
        ``gram_block`` + matmul — always the case for ``KrrServer`` waves);
        past that bound it falls back to k fused ``knm_matvec`` calls so a
        huge offline batch streams instead of materializing n*M floats.
        """
        spec = backend if backend is not None else self.backend
        be = resolve_backend(spec, n=x.shape[0])
        if self.alpha.ndim == 1:
            return be.knm_matvec(self.kernel, x, self.centers, self.alpha)
        if x.shape[0] * self.centers.shape[0] <= _PREDICT_GRAM_ELEMS:
            return be.gram_block(self.kernel, x, self.centers) @ self.alpha
        return jnp.stack([be.knm_matvec(self.kernel, x, self.centers, self.alpha[:, j])
                          for j in range(self.alpha.shape[1])], axis=1)


def falkon_fit(
    kernel: Kernel,
    x: Array,
    y: Array,
    centers: Array,
    lam: float,
    *,
    a_diag: Array | None = None,
    iters: int = 20,
    backend: BackendLike = None,
    callback: Callable[[int, FalkonModel], None] | None = None,
    fused: bool | None = None,
) -> FalkonModel:
    """Fit FALKON (uniform A=I) or FALKON-BLESS (A from Alg. 1/2).

    ``backend`` selects the K_nM operator implementation — an instance, a
    registry name ("jnp" | "pallas" | "sharded"), or None for the platform
    heuristic (repro.core.backend.default_backend).

    ``fused`` selects the whole-fit compilation path (see module docstring):
    None (default) takes it automatically when the backend is jit-safe and no
    ``callback`` needs the host CG loop; True forces it (raising if the
    backend cannot be traced); False forces the host-driven path.

    ``y`` may be (n,) or (n, k): multi-output targets solve one CG per
    column against the same centers. The columns share one *compile* (every
    column after the first hits the fused cache on the identical shape
    bucket) but are otherwise independent full solves — each re-derives the
    preconditioner and re-streams K_nM. Batching the right-hand sides
    through a multi-RHS CG is an open perf item (ROADMAP).
    """
    n = x.shape[0]
    m = centers.shape[0]
    backend = resolve_backend(backend, n=n)
    if y.ndim == 2:
        if callback is not None:
            raise ValueError("per-iteration callback is single-output only; "
                             "fit columns separately to trace them")
        cols = [falkon_fit(kernel, x, y[:, j], centers, lam, a_diag=a_diag,
                           iters=iters, backend=backend, fused=fused)
                for j in range(y.shape[1])]
        return FalkonModel(centers=centers,
                           alpha=jnp.stack([c.alpha for c in cols], axis=1),
                           kernel=kernel, backend=backend)
    a_diag = jnp.ones((m,), x.dtype) if a_diag is None else a_diag
    if fused is None:
        fused = backend.jit_safe and callback is None
    if fused:
        if not backend.jit_safe:
            raise ValueError(f"fused=True needs a jit-safe backend, got {backend.name!r}")
        if callback is not None:
            raise ValueError("the fused fit has no host CG loop; "
                             "pass fused=False to use callback")
        block = _fit_block(backend)
        pad = (-n) % block
        # yp is donated by _fused_falkon_solve, so it must be a fresh buffer
        # even when the bucket needs no padding (x is shared, never donated).
        yp = jnp.pad(y, (0, pad)) if pad else y + jnp.zeros((), y.dtype)
        alpha = _fused_falkon_solve(
            kernel, jnp.pad(x, ((0, pad), (0, 0))), yp, centers, a_diag,
            jnp.asarray(lam, jnp.float32), jnp.asarray(n, jnp.int32),
            iters=iters, backend=backend, block=block)
        return FalkonModel(centers=centers, alpha=alpha, kernel=kernel, backend=backend)
    prec = make_preconditioner(kernel, centers, a_diag, lam, n)
    kmm = backend.gram_block(kernel, centers, centers)
    quad, kty = backend.knm_operators(kernel, x, centers, y)

    def matvec(v: Array) -> Array:
        u = prec.apply(v)
        w = quad(u) + lam * n * (kmm @ u)
        return prec.apply_t(w)

    b = prec.apply_t(kty)
    cb = None
    if callback is not None:
        def cb(i, beta):  # noqa: E731 — host-side metric hook
            callback(i, FalkonModel(centers=centers, alpha=prec.apply(beta),
                                    kernel=kernel, backend=backend))
    beta = cg(matvec, b, iters, callback=cb)
    return FalkonModel(centers=centers, alpha=prec.apply(beta), kernel=kernel,
                       backend=backend)


def falkon_bless_fit(key: Array, kernel: Kernel, x: Array, y: Array, lam_bless: float,
                     lam_falkon: float, *, iters: int = 20, q2: float = 3.0,
                     m_cap: int | None = None, backend: BackendLike = None,
                     callback=None) -> FalkonModel:
    """FALKON-BLESS end-to-end (the paper's lam_bless >> lam_falkon trick,
    Sec. 4). Thin shim over the ``repro.api`` front door — equivalent to
    ``FalkonRegressor(sampler=BlessSampler(lam=lam_bless, ...))`` — kept for
    source compatibility; tests/test_api.py proves the paths bit-identical.

    The upward delegation is deliberate: the sampler+solver *composition*
    has exactly one implementation (the estimator), so shim and front door
    cannot drift. The import is lazy/call-time, keeping module import order
    acyclic (api imports core at module scope, never the reverse).
    """
    from ..api.estimators import FalkonRegressor, FitConfig  # api sits above core
    from ..api.samplers import BlessSampler

    est = FalkonRegressor(
        kernel=kernel,
        sampler=BlessSampler(lam=lam_bless, q2=q2, m_cap=m_cap),
        config=FitConfig(lam=lam_falkon, iters=iters, backend=backend),
    )
    return est.fit(x, y, key=key, callback=callback).model_
