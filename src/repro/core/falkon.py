"""FALKON with generalized (BLESS-weighted) preconditioner — paper Sec. 3 / App. B.

Solves Nystrom-KRR

    alpha = (K_nM^T K_nM + lam n K_MM)^+ K_nM^T y        (Eq. 13, lam*n conv.)

by conjugate gradient on the preconditioned system (Def. 3)

    W beta = b,   W = B^T (K_nM^T K_nM + lam n K_MM) B,  b = B^T K_nM^T y,

with the generalized preconditioner of Def. 2 / Eq. (15):

    B = (1/sqrt(n)) A^{-1/2} T^{-1} R^{-1},
    T = chol_u(A^{-1/2} K_MM A^{-1/2}),   R = chol_u(T T^T / M + lam I)

so that B B^T = (n/M K_MM A^{-1} K_MM + lam n K_MM)^{-1}.

The CG matvec never materializes K_nM: the K_nM^T K_nM v / K_nM^T y
contractions come from the kernel-operator ``Backend`` seam
(``repro.core.backend``) — the local pure-jnp streamer, the Pallas fused
kernel (repro.kernels.falkon_matvec), or the shard_map data-parallel one in
core/distributed.py. All three share this file's CG loop.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from .gram import BackendLike, Kernel, resolve_backend
from .leverage import CenterSet, _chol_with_jitter

Array = jax.Array


class Preconditioner(NamedTuple):
    """Factors of Def. 2, Example 1.3 (eigendecomposition branch).

    BLESS samples centers *with replacement*, so K_MM is routinely rank
    deficient (duplicate rows); the eigh-based partial isometry Q with rank
    truncation is the paper's own answer (Def. 2 requires only Q^T Q = I,
    q <= M) and is fp32-robust where the Cholesky branch explodes.
    """

    q_iso: Array  # (M, q) partial isometry
    t_diag: Array  # (q,)  T = diag(sqrt(eig))
    r_diag: Array  # (q,)  R = diag(sqrt(eig/M + lam))
    inv_sqrt_a: Array  # (M,) diag(A)^{-1/2}
    n: int

    def apply(self, v: Array) -> Array:
        """B v = (1/sqrt n) A^{-1/2} Q T^{-1} R^{-1} v,  v (q,)."""
        u = self.q_iso @ (v / (self.t_diag * self.r_diag))
        return self.inv_sqrt_a * u / jnp.sqrt(self.n)

    def apply_t(self, v: Array) -> Array:
        """B^T v,  v (M,) -> (q,)."""
        u = self.q_iso.T @ (self.inv_sqrt_a * v / jnp.sqrt(self.n))
        return u / (self.t_diag * self.r_diag)


def make_preconditioner(kernel: Kernel, z: Array, a_diag: Array, lam: float, n: int,
                        *, rank_tol: float = 1e-5) -> Preconditioner:
    """Def. 2 factors for centers z (M, d) with weights diag(A) = a_diag.

    eigh of A^{-1/2} K_MM A^{-1/2}; eigenvalues below rank_tol * max are
    dropped (q = numerical rank), exactly Example 1.3 with q = rank(K_MM).
    """
    m = z.shape[0]
    kmm = kernel.cross(z, z).astype(jnp.float32)
    inv_sqrt_a = (1.0 / jnp.sqrt(a_diag)).astype(jnp.float32)
    kt = kmm * (inv_sqrt_a[:, None] * inv_sqrt_a[None, :])
    eig, vec = jnp.linalg.eigh(kt)
    floor = jnp.maximum(eig[-1], 1e-30) * rank_tol
    keep = eig > floor
    # jit-friendly fixed shapes: keep all M columns but neutralize dropped
    # directions (T entry -> 1, Q column -> 0): B then annihilates them.
    t_diag = jnp.sqrt(jnp.where(keep, eig, 1.0))
    r_diag = jnp.sqrt(jnp.where(keep, eig / m + lam, 1.0))
    q_iso = vec * keep[None, :].astype(vec.dtype)
    return Preconditioner(q_iso, t_diag, r_diag, inv_sqrt_a, n)


# ---------------------------------------------------------------------------
# K_nM operators
# ---------------------------------------------------------------------------

KnmOp = Callable[[Array], tuple[Array, Array]]
# v (M,) -> (K_nM^T K_nM v  (M,),  K_nM^T y (M,))  -- the second returned once


def local_knm_quadratic(kernel: Kernel, x: Array, z: Array, *, block: int = 8192) -> Callable[[Array], Array]:
    """v -> K_nM^T (K_nM v), streaming x in row blocks (pure-jnp reference)."""
    n, m = x.shape[0], z.shape[0]
    pad = (-n) % block
    xp = jnp.pad(x, ((0, pad), (0, 0)))
    nb = xp.shape[0] // block
    valid = (jnp.arange(nb * block) < n).reshape(nb, block)

    def op(v: Array) -> Array:
        def body(carry, args):
            xb, mb = args
            g = kernel.cross(xb, z) * mb[:, None]
            return carry + g.T @ (g @ v), None

        out, _ = jax.lax.scan(body, jnp.zeros((m,), v.dtype),
                              (xp.reshape(nb, block, -1), valid))
        return out

    return op


def local_knm_t(kernel: Kernel, x: Array, z: Array, y: Array, *, block: int = 8192) -> Array:
    """K_nM^T y, streamed."""
    n, m = x.shape[0], z.shape[0]
    pad = (-n) % block
    xp = jnp.pad(x, ((0, pad), (0, 0)))
    yp = jnp.pad(y, (0, pad))
    nb = xp.shape[0] // block

    def body(carry, args):
        xb, yb = args
        return carry + kernel.cross(xb, z).T @ yb, None

    out, _ = jax.lax.scan(body, jnp.zeros((m,), x.dtype),
                          (xp.reshape(nb, block, -1), yp.reshape(nb, block)))
    return out


# ---------------------------------------------------------------------------
# Conjugate gradient
# ---------------------------------------------------------------------------


def cg(matvec: Callable[[Array], Array], b: Array, iters: int,
       callback: Callable[[int, Array], None] | None = None) -> Array:
    """Plain CG on SPD ``matvec``; fixed iteration count (paper uses t ~ log n).

    With ``callback`` the loop runs on host (per-iteration metrics for the
    Fig. 4/5 analogues); otherwise it is a single jitted lax.fori_loop.
    """
    if callback is not None:
        beta = jnp.zeros_like(b)
        r = b
        p = r
        rs = jnp.dot(r, r)
        for i in range(iters):
            ap = matvec(p)
            alpha = rs / jnp.maximum(jnp.dot(p, ap), 1e-30)
            beta = beta + alpha * p
            r = r - alpha * ap
            rs_new = jnp.dot(r, r)
            p = r + (rs_new / jnp.maximum(rs, 1e-30)) * p
            rs = rs_new
            callback(i, beta)
        return beta

    def body(_, state):
        beta, r, p, rs = state
        ap = matvec(p)
        alpha = rs / jnp.maximum(jnp.dot(p, ap), 1e-30)
        beta = beta + alpha * p
        r = r - alpha * ap
        rs_new = jnp.dot(r, r)
        p = r + (rs_new / jnp.maximum(rs, 1e-30)) * p
        return beta, r, p, rs_new

    init = (jnp.zeros_like(b), b, b, jnp.dot(b, b))
    return jax.lax.fori_loop(0, iters, body, init)[0]


# ---------------------------------------------------------------------------
# FALKON estimator
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class FalkonModel:
    centers: Array  # (M, d)
    alpha: Array  # (M,)
    kernel: Kernel

    def predict(self, x: Array, *, block: int = 8192) -> Array:
        n = x.shape[0]
        pad = (-n) % block
        xp = jnp.pad(x, ((0, pad), (0, 0)))
        out = jax.lax.map(
            lambda xb: self.kernel.cross(xb, self.centers) @ self.alpha,
            xp.reshape(-1, block, x.shape[1]),
        )
        return out.reshape(-1)[:n]


def falkon_fit(
    kernel: Kernel,
    x: Array,
    y: Array,
    centers: Array,
    lam: float,
    *,
    a_diag: Array | None = None,
    iters: int = 20,
    backend: BackendLike = None,
    callback: Callable[[int, FalkonModel], None] | None = None,
) -> FalkonModel:
    """Fit FALKON (uniform A=I) or FALKON-BLESS (A from Alg. 1/2).

    ``backend`` selects the K_nM operator implementation — an instance, a
    registry name ("jnp" | "pallas" | "sharded"), or None for the platform
    heuristic (repro.core.backend.default_backend).
    """
    n = x.shape[0]
    m = centers.shape[0]
    backend = resolve_backend(backend, n=n)
    a_diag = jnp.ones((m,), x.dtype) if a_diag is None else a_diag
    prec = make_preconditioner(kernel, centers, a_diag, lam, n)
    kmm = backend.gram_block(kernel, centers, centers)
    quad, kty = backend.knm_operators(kernel, x, centers, y)

    def matvec(v: Array) -> Array:
        u = prec.apply(v)
        w = quad(u) + lam * n * (kmm @ u)
        return prec.apply_t(w)

    b = prec.apply_t(kty)
    cb = None
    if callback is not None:
        def cb(i, beta):  # noqa: E731 — host-side metric hook
            callback(i, FalkonModel(centers=centers, alpha=prec.apply(beta), kernel=kernel))
    beta = cg(matvec, b, iters, callback=cb)
    return FalkonModel(centers=centers, alpha=prec.apply(beta), kernel=kernel)


def falkon_bless_fit(key: Array, kernel: Kernel, x: Array, y: Array, lam_bless: float,
                     lam_falkon: float, *, iters: int = 20, q2: float = 3.0,
                     m_cap: int | None = None, backend: BackendLike = None,
                     callback=None) -> FalkonModel:
    """FALKON-BLESS end-to-end: BLESS centers/weights at lam_bless, CG at
    lam_falkon (the paper's lam_bless >> lam_falkon trick, Sec. 4)."""
    from .bless import bless

    backend = resolve_backend(backend, n=x.shape[0])
    res = bless(key, x, kernel, lam_bless, q2=q2, m_cap=m_cap, backend=backend)
    lvl = res.final
    m = lvl.m_h
    idx = lvl.centers.idx[:m]
    a = lvl.centers.weight[:m]
    return falkon_fit(kernel, x, y, x[idx], lam_falkon, a_diag=a, iters=iters,
                      backend=backend, callback=callback)
