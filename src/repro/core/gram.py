"""Kernel (Gram) computations and the kernel-operator backend registry.

The paper works with a bounded PSD kernel ``K(x, x') <= kappa^2`` (Eq. 17).
``Kernel`` is a tiny pytree so jitted core functions retrace only when the
kernel *family* changes, not when its bandwidth does. Families themselves
live in the extensible registry ``repro.families`` (re-exported here):
each ``KernelFamily`` contributes the jnp formula *and* the Pallas tile
epilogue, so a registered family runs on all three backends.

The blockwise entry points here are the pure-jnp reference path; the same
contractions are served by the Pallas kernels (``repro.kernels.gram`` /
``repro.kernels.falkon_matvec``) and the shard_map data-parallel path
(``repro.core.distributed``) through the ``Backend`` implementations in
``repro.core.backend``. This module owns only the *registry* so the low
levels (leverage, bless, falkon) can resolve a backend by name without
importing the backend module at import time (it imports all of them).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import TYPE_CHECKING, Callable, Union

import jax
import jax.numpy as jnp

from ..families import (  # noqa: F401 — re-exported public API
    KernelFamily,
    diag_pre,
    get_family,
    kernel_family_names,
    register_kernel_family,
)

if TYPE_CHECKING:  # pragma: no cover — type-only, avoids the import cycle
    from .backend import Backend

BackendLike = Union["Backend", str, None]


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class Kernel:
    """A bounded positive-definite kernel ``k(x, z)``.

    Attributes:
      name: kernel family, resolved from the ``repro.families`` registry
        (``kernel_family_names()`` enumerates what is available; gaussian,
        laplacian, linear, matern32 and cauchy ship built in).
      sigma: bandwidth (ignored by bandwidth-free families, e.g. "linear").
      kappa_sq: uniform bound on ``k(x, x)`` (1.0 for the unit-diagonal
        families; must be supplied for "linear" if inputs are not normalized).
    """

    name: str = "gaussian"
    sigma: float = 1.0
    kappa_sq: float = 1.0

    # -- pytree plumbing (name/kappa_sq static, sigma traced) ---------------
    def tree_flatten(self):
        return (jnp.asarray(self.sigma),), (self.name, self.kappa_sq)

    @classmethod
    def tree_unflatten(cls, aux, children):
        name, kappa_sq = aux
        return cls(name=name, sigma=children[0], kappa_sq=kappa_sq)

    @property
    def family(self) -> KernelFamily:
        """The registered family (raises with the registry listed on typos)."""
        return get_family(self.name)

    # -- API -----------------------------------------------------------------
    def cross(self, x: jax.Array, z: jax.Array) -> jax.Array:
        """Gram block ``k(x_i, z_j)`` of shape (n, m)."""
        fam = self.family
        if fam.dot_only:
            return fam.epilogue(x @ z.T, fam.inv_scale(self.sigma))
        return fam.epilogue(sq_dists(x, z), fam.inv_scale(self.sigma))

    def cross_unfused(self, x: jax.Array, z: jax.Array) -> jax.Array:
        """``cross`` with the epilogue kept out of the XLA:CPU broadcast
        fusion (see ``_apply_epilogue``) — elementwise-identical, much
        faster for exp-based families on CPU. The extra ``lax.map`` level
        makes it unsafe inside deeply nested control flow (e.g. the CG
        while-loop), so hot *leaf* contractions opt in explicitly."""
        fam = self.family
        pre = x @ z.T if fam.dot_only else sq_dists(x, z)
        return _apply_epilogue(fam, pre, fam.inv_scale(self.sigma))

    def diag(self, x: jax.Array) -> jax.Array:
        """``k(x_i, x_i)`` of shape (n,)."""
        fam = self.family
        if fam.unit_diag:
            return jnp.ones((x.shape[0],), x.dtype)
        return fam.epilogue(diag_pre(fam, x), fam.inv_scale(self.sigma))

    def gram(self, x: jax.Array) -> jax.Array:
        return self.cross(x, x)


_EPILOGUE_BLOCKS = 8


def _apply_epilogue(fam: KernelFamily, pre: jax.Array, c) -> jax.Array:
    """Apply a family epilogue to a Gram pre-activation block.

    On CPU the epilogue goes through a ``lax.map`` over row blocks rather
    than straight elementwise application: XLA:CPU fuses the epilogue into
    the distance broadcast loop and then emits *scalar* transcendental
    calls (~4x the whole block's cost for exp-based families); the loop
    body boundary keeps the epilogue a standalone op, which lowers to the
    vectorized libm kernels. Elementwise results are identical. Other
    platforms (and shapes the block count doesn't divide) take the plain
    fused path.
    """
    n = pre.shape[0] if pre.ndim == 2 else 0
    if (jax.default_backend() != "cpu" or n < 512
            or n % _EPILOGUE_BLOCKS != 0):
        return fam.epilogue(pre, c)
    nb = _EPILOGUE_BLOCKS
    blocks = pre.reshape(nb, n // nb, pre.shape[1])
    return jax.lax.map(lambda b: fam.epilogue(b, c), blocks).reshape(pre.shape)


def sq_dists(x: jax.Array, z: jax.Array) -> jax.Array:
    """Pairwise squared Euclidean distances, MXU-friendly form.

    ||x - z||^2 = ||x||^2 + ||z||^2 - 2 x.z  — one (n,d)x(d,m) matmul plus
    rank-1 updates; clamped at 0 against fp cancellation.
    """
    xn = jnp.sum(x * x, axis=-1)[:, None]
    zn = jnp.sum(z * z, axis=-1)[None, :]
    d2 = xn + zn - 2.0 * (x @ z.T)
    return jnp.maximum(d2, 0.0)


def make_kernel(name: str = "gaussian", sigma: float = 1.0, kappa_sq: float = 1.0) -> Kernel:
    """Build a ``Kernel`` after validating ``name`` against the family
    registry (unknown names raise with the registry enumerated).

    ``sigma`` is the bandwidth (ignored by bandwidth-free families);
    ``kappa_sq`` the uniform bound on k(x, x) — supply it for "linear" on
    unnormalized inputs (Eq. 17 candidate-set sizing depends on it).
    """
    get_family(name)  # fail fast with the registered families enumerated
    return Kernel(name=name, sigma=sigma, kappa_sq=kappa_sq)


# ---------------------------------------------------------------------------
# Backend registry
#
# ``repro.core.backend`` registers its implementations here on import; the
# callers (leverage / bless / falkon / benchmarks) resolve by name or pass an
# instance through. Keeping the dict in this leaf module breaks the cycle
# backend.py -> {leverage, falkon, distributed} -> gram.
# ---------------------------------------------------------------------------

_BACKEND_REGISTRY: dict[str, Callable[[], "Backend"]] = {}


def register_backend(name: str, factory: Callable[[], "Backend"]) -> None:
    """Register a zero-arg factory for ``resolve_backend(name)``."""
    _BACKEND_REGISTRY[name] = factory


def backend_names() -> list[str]:
    _ensure_backends_loaded()
    return sorted(_BACKEND_REGISTRY)


def resolve_backend(spec: BackendLike = None, *, n: int | None = None) -> "Backend":
    """Resolve a backend spec: instance (passthrough), name, or None (auto).

    ``None`` picks ``backend.default_backend(n)`` — the platform/size
    heuristic — so every core entry point gets hardware-appropriate
    contractions without callers naming one. ``n`` is the dataset row count
    when the caller knows it.

    Composite specs ``"outer:inner"`` (e.g. ``"stream:pallas"``) resolve the
    outer name, then hand it the resolved inner via its ``with_inner`` hook —
    how the out-of-core streamer composes with a per-tile backend. The inner
    part may itself be composite.
    """
    if spec is None:
        _ensure_backends_loaded()
        from .backend import default_backend

        return default_backend(n)
    if isinstance(spec, str):
        _ensure_backends_loaded()
        outer_name, _, inner_spec = spec.partition(":")
        try:
            outer = _BACKEND_REGISTRY[outer_name]()
        except KeyError:
            raise ValueError(
                f"unknown backend {outer_name!r}; registered: {sorted(_BACKEND_REGISTRY)}"
            ) from None
        if not inner_spec:
            return outer
        if not hasattr(outer, "with_inner"):
            raise ValueError(
                f"backend {outer_name!r} is not composable (no with_inner); "
                f"cannot resolve {spec!r}")
        return outer.with_inner(resolve_backend(inner_spec, n=n))
    return spec


def _ensure_backends_loaded() -> None:
    from . import backend  # noqa: F401 — import side effect: registration


@partial(jax.jit, static_argnames=("block",))
def blocked_cross(kernel: Kernel, x: jax.Array, z: jax.Array, *, block: int = 4096) -> jax.Array:
    """Gram ``k(X, Z)`` computed in row blocks of ``x`` to bound peak memory.

    Used when (n, m) is too large for one materialized intermediate; the
    distance matrix per block is (block, m).
    """
    n = x.shape[0]
    if n <= block:
        return kernel.cross_unfused(x, z)
    pad = (-n) % block
    xp = jnp.pad(x, ((0, pad), (0, 0)))
    xb = xp.reshape(-1, block, x.shape[1])
    out = jax.lax.map(lambda xi: kernel.cross_unfused(xi, z), xb)
    return out.reshape(-1, z.shape[0])[:n]
