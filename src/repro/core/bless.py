"""BLESS (Alg. 1) and BLESS-R (Alg. 2) — bottom-up leverage score sampling.

Faithful implementations of the paper's Algorithms 1 and 2, restructured so
the host loop touches the device as little as possible. The ladder itself
runs on the host (H ~ log(lam0/lam)/log q levels); each level is two jitted
phases on size-bucketed buffers:

  * a *score* phase — candidate draw, Eq. 3 scoring through the
    ``Backend.rls_scores`` seam (the fused Pallas kernel on TPU), and the
    d_h reduction, all inside one compiled call;
  * a *sample* phase — the with-replacement categorical draw (Alg. 1) or
    acceptance compaction (Alg. 2) and the A_h weights.

  Between the phases the host fetches exactly the scalars it needs to pick
the next static shapes (d_h -> M_h, the distinct-center count -> the next
level's score buffer), so there are O(1) device syncs per level instead of
O(1) per array.

Buffers use *quarter-pow2* buckets (``_bucket``): pow2 up to 32, then the
smallest of {5/8, 3/4, 7/8, 1} * pow2 that fits. Padding waste drops from
<= 2x to <= 1.25x while the jit cache stays O(log) sized — a draw of 1045
candidates runs on a 1280 buffer, not 2048. ``_LADDER_TRACES`` counts
ladder retraces (the analogue of ``falkon._FUSED_FIT_TRACES``): repeating a
ladder at the same (n, kernel, lam, q*) hits the cache end to end.

Two exact-optimization notes (distributionally identical to the paper's
pseudocode, DESIGN.md §8):

  * when a level wants more uniform candidates than there are points
    (R_h >= n), the score phase evaluates each point once and carries the
    multiplicity c_i of the uniform draw instead of scoring duplicate rows
    (Alg. 1 line 6 at R_h ~ q1 n would score each point ~q1 times);
  * the Alg. 1 center sets are multisets; the *internal* scorer merges
    duplicate centers before the Cholesky via the Woodbury push-through
    (merged reg = harmonic sum of the duplicates' lam n A_jj), shrinking
    the (M, M) factor to the distinct-center count. The public
    ``CenterSet`` keeps the raw multiset — FALKON and Eq. 3 consumers see
    exactly the paper's (J_h, A_h).

Paper-vs-practice constants: Thm. 1's q1/q2 include union-bound log factors
that the paper's own experiments do not use (Sec. 4 reaches M ~ 1e4 centers
at n = 7e4). ``theory_constants(t, q, n, H, delta)`` reproduces Thm. 1's
values; the defaults are the practical ones used in our Fig. 1/2 analogues.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from .gram import BackendLike, Kernel, resolve_backend
from .leverage import _SCORE_FLOOR, CenterSet
from .sampling import categorical

Array = jax.Array

#: Retrace counter for the jitted ladder phases (incremented at trace time,
#: mirroring ``falkon._FUSED_FIT_TRACES``). Host-driven backends bump it per
#: call; the zero-retrace guard in tests pins the jnp path.
_LADDER_TRACES = 0

#: Kept name: the Alg. 1 line-9 draw is *with replacement*, i.e. the jitted
#: inverse-CDF categorical (see ``repro.core.sampling`` for why it is not a
#: Gumbel-top-k, which samples without replacement).
_multinomial = categorical


@dataclasses.dataclass(frozen=True)
class BlessLevel:
    """One rung of the ladder: accurate scores at scale lam_h."""

    lam: float
    centers: CenterSet  # (J_h, A_h) on a padded buffer
    d_h: float  # n/R_h * sum of candidate scores  (≈ d_eff(lam_h))
    m_h: int  # |J_h|
    r_h: int  # |U_h|


@dataclasses.dataclass(frozen=True)
class BlessResult:
    levels: list[BlessLevel]
    lam_path: list[float]

    @property
    def final(self) -> BlessLevel:
        return self.levels[-1]

    def scores(self, kernel: Kernel, x_all: Array, lam: float | None = None,
               *, backend: BackendLike = None) -> Array:
        """Approximate leverage scores for every point at the final scale."""
        from .leverage import approx_rls_all

        lvl = self.final
        return approx_rls_all(kernel, x_all, lvl.centers, jnp.asarray(lam or lvl.lam),
                              backend=backend)


def theory_constants(t: float, q: float, n: int, h: int, delta: float = 0.1):
    """Thm. 1 (Alg. 1) constants: (q1, q2)."""
    q2 = 12.0 * q * (2 * t + 1) ** 2 / t**2 * (1 + t) * math.log(12 * h * n / delta)
    q1 = 5.0 * q2 / (q * (1 + t))
    return q1, q2


def lam_ladder(lam: float, lam0: float, q: float) -> list[float]:
    """Geometric ladder lam_0 > ... > lam_H = lam (lam_h = lam_{h-1}/q)."""
    h = max(1, math.ceil(math.log(lam0 / lam) / math.log(q)))
    lams = [lam0 / q**i for i in range(1, h)]
    lams.append(lam)  # pin the final level exactly at lam
    return lams


def _pow2(x: int) -> int:
    return 1 << max(0, (int(x) - 1)).bit_length()


def _bucket(x: int) -> int:
    """Quarter-pow2 size bucket: pow2 up to 32, then the smallest of
    {5/8, 3/4, 7/8, 1} * next-pow2 that fits. At most 4 buckets per octave
    keeps the jit cache O(log) while capping padding waste at 25%."""
    x = max(1, int(x))
    p = _pow2(x)
    if p <= 32:
        return p
    for c in (5 * p // 8, 3 * p // 4, 7 * p // 8):
        if c >= x:
            return c
    return p


# =============================================================================
# Shared level machinery
# =============================================================================


def _dedup_centers(centers: CenterSet, lamn: Array, dbuf: int):
    """Merge duplicate centers of an Alg. 1 multiset into a (dbuf,) buffer.

    Exact via the Woodbury push-through: duplicate columns j of the same
    point with regularized diagonals r_j = lam n A_jj collapse to one column
    with r = 1 / sum_j (1/r_j) (harmonic; a singleton is unchanged). The
    caller guarantees dbuf >= the distinct count (it fetched it when the
    level was sampled); surplus duplicates would be silently dropped
    otherwise, so the driver always buckets the fetched count up.
    """
    mbuf = centers.idx.shape[0]
    sentinel = jnp.iinfo(jnp.int32).max
    order = jnp.argsort(jnp.where(centers.mask, centers.idx, sentinel))
    sidx = centers.idx[order]
    svalid = centers.mask[order]
    sinv = jnp.where(svalid, 1.0 / (lamn * centers.weight[order]), 0.0)
    prev = jnp.concatenate([jnp.full((1,), -1, sidx.dtype), sidx[:-1]])
    first = svalid & (sidx != prev)
    seg = jnp.cumsum(first.astype(jnp.int32)) - 1
    n_dd = jnp.sum(first.astype(jnp.int32))
    tgt = jnp.where(svalid, seg, dbuf)  # out-of-bounds scatters drop
    dd_idx = jnp.zeros((dbuf,), jnp.int32).at[tgt].set(sidx, mode="drop")
    dd_inv = jnp.zeros((dbuf,), jnp.float32).at[tgt].add(sinv, mode="drop")
    dd_mask = jnp.arange(dbuf) < n_dd
    dd_reg = jnp.where(dd_mask, 1.0 / jnp.maximum(dd_inv, 1e-30), 1.0)
    return dd_idx, dd_mask, dd_reg


def _rls_dedup(kernel, x_cand, cand_mask, x_all, centers, lamn, *, backend, dbuf):
    """Eq. 3 scores of candidates against a (possibly multiset) center set,
    deduplicated internally, through ``backend.rls_scores``. Clipped to
    [_SCORE_FLOOR, 1]; 0 on invalid candidate slots.

    Host-resident ``x_all`` (a ``repro.stream.ChunkStore``) takes a Python
    branch instead of ``lax.cond``: the cond traces BOTH branches, and a
    traced center gather would force the whole store onto the device. Only
    reachable on non-jit-safe backends (the stream driver), so the jitted
    ladder phases never see it; the empty-center case routes through
    ``rls_scores`` with an all-masked buffer (exactly K_ii / lamn) so a
    chunked ``x_cand`` never meets a raw ``kernel.diag``.
    """
    if not isinstance(x_all, jax.Array):
        if int(centers.count) > 0:
            dd_idx, dd_mask, dd_reg = _dedup_centers(centers, lamn, dbuf)
            s = backend.rls_scores(kernel, x_cand, x_all[dd_idx], dd_mask,
                                   dd_reg, lamn)
        else:
            s = backend.rls_scores(
                kernel, x_cand, x_all[np.zeros((dbuf,), np.int32)],
                jnp.zeros((dbuf,), bool), jnp.ones((dbuf,), jnp.float32), lamn)
        return jnp.where(cand_mask, jnp.clip(s, _SCORE_FLOOR, 1.0), 0.0)

    def no_centers(_):
        return kernel.diag(x_cand) / lamn

    def with_centers(_):
        dd_idx, dd_mask, dd_reg = _dedup_centers(centers, lamn, dbuf)
        return backend.rls_scores(kernel, x_cand, x_all[dd_idx], dd_mask,
                                  dd_reg, lamn)

    s = jax.lax.cond(centers.count > 0, with_centers, no_centers, None)
    s = jnp.clip(s, _SCORE_FLOOR, 1.0)
    return jnp.where(cand_mask, s, 0.0)


# =============================================================================
# Algorithm 1 — BLESS (with replacement)
# =============================================================================


def _bless_score_impl(k_u, x, kernel, centers, lam_h, r_h, *,
                      backend, rbuf, dbuf, counts):
    """Level score phase: candidate draw + Eq. 3 scores + the d_h reduction.

    ``counts=True`` is the R_h >= n regime: every point is scored once and
    the uniform draw only contributes multiplicities c_i (scatter-add), so
    the quadform runs over n rows instead of R_h > n duplicate rows.
    Returns (cand_idx, s, wvec, tot, d_h) with wvec = c * s the unnormalized
    sampling weights of Alg. 1 line 8.
    """
    global _LADDER_TRACES
    _LADDER_TRACES += 1
    n = x.shape[0]
    lamn = lam_h * n
    draws = jax.random.randint(k_u, (rbuf,), 0, n)
    if counts:
        cand_idx = jnp.arange(n, dtype=jnp.int32)
        cand_mask = jnp.ones((n,), bool)
        x_cand = x
        slot = jnp.where(jnp.arange(rbuf) < r_h, draws, n)
        c = jnp.zeros((n,), jnp.float32).at[slot].add(1.0, mode="drop")
    else:
        cand_idx = draws.astype(jnp.int32)
        cand_mask = jnp.arange(rbuf) < r_h
        x_cand = x[cand_idx]
        c = cand_mask.astype(jnp.float32)
    s = _rls_dedup(kernel, x_cand, cand_mask, x, centers, lamn,
                   backend=backend, dbuf=dbuf)
    wvec = c * s
    tot = jnp.maximum(jnp.sum(wvec), 1e-30)
    d_h = n / r_h.astype(jnp.float32) * tot
    return cand_idx, s, wvec, tot, d_h


_bless_score = partial(jax.jit, static_argnames=("backend", "rbuf", "dbuf",
                                                 "counts"))(_bless_score_impl)


@partial(jax.jit, static_argnames=("mbuf", "n"))
def _bless_sample(k_j, cand_idx, s, wvec, tot, r_h, m_h, *, mbuf, n):
    """Level sample phase (Alg. 1 lines 9-10): M_h categorical draws from
    wvec (with replacement), the A_h weights, and the distinct-center count
    the host needs to size the next level's dedup buffer."""
    global _LADDER_TRACES
    _LADDER_TRACES += 1
    pos = categorical(k_j, wvec, mbuf)
    j_mask = jnp.arange(mbuf) < m_h
    scale = r_h.astype(jnp.float32) * m_h.astype(jnp.float32) / n
    w = jnp.where(j_mask, scale * s[pos] / tot, 1.0)
    idx = cand_idx[pos].astype(jnp.int32)
    center_set = CenterSet(
        idx=idx,
        weight=w.astype(jnp.float32),
        mask=j_mask,
        count=m_h.astype(jnp.int32),
    )
    sort_key = jnp.sort(jnp.where(j_mask, idx, jnp.iinfo(jnp.int32).max))
    prev = jnp.concatenate([jnp.full((1,), -1, sort_key.dtype), sort_key[:-1]])
    n_distinct = jnp.sum((sort_key != prev) & (jnp.arange(mbuf) < m_h))
    return center_set, n_distinct


def bless(
    key: Array,
    x: Array,
    kernel: Kernel,
    lam: float,
    *,
    q: float = 2.0,
    q1: float = 3.0,
    q2: float = 3.0,
    lam0: float | None = None,
    t: float = 1.0,
    m_cap: int | None = None,
    backend: BackendLike = None,
) -> BlessResult:
    """Bottom-up Leverage Score Sampling (paper Alg. 1).

    Args:
      key: PRNG key.
      x: (n, d) dataset.
      kernel: bounded PSD kernel.
      lam: target regularization (the paper's lambda).
      q: ladder step (> 1).
      q1: candidate-set multiplier, R_h = q1 * min(kappa^2/lam_h, n).
      q2: center multiplier, M_h = q2 * d_h.
      lam0: ladder start; defaults to the paper's kappa^2/min(t, 1).
      t: target multiplicative accuracy (only sets the default lam0).
      m_cap: optional hard cap on M_h (memory guard for benchmarks).
      backend: kernel-operator backend for the Eq. 3 scorer — an instance,
        a registry name ("jnp" | "pallas" | "sharded"), or None for the
        platform heuristic (repro.core.backend.default_backend).

    Returns:
      BlessResult with one BlessLevel per rung — the whole regularization
      path {lam_h}, the paper's "computed at once" advantage.
    """
    n = x.shape[0]
    kap2 = float(kernel.kappa_sq)
    lam0 = kap2 / min(t, 1.0) if lam0 is None else lam0
    lams = lam_ladder(lam, lam0, q)
    backend = resolve_backend(backend, n=n)
    score_fn = _bless_score if backend.jit_safe else _bless_score_impl

    centers = CenterSet.empty(1)
    dbuf = 1
    levels: list[BlessLevel] = []
    for lam_h in lams:
        key, k_u, k_j = jax.random.split(key, 3)
        # -- line 4/5: uniform candidates U_h, R_h = q1 * min(kappa^2/lam_h, n)
        r_h = max(8, int(math.ceil(q1 * min(kap2 / lam_h, n))))
        rbuf = _bucket(r_h)
        counts = n <= rbuf  # score each point once, carry multiplicities
        cand_idx, s, wvec, tot, d_dev = score_fn(
            k_u, x, kernel, centers, jnp.asarray(lam_h, jnp.float32),
            jnp.asarray(r_h, jnp.int32),
            backend=backend, rbuf=rbuf, dbuf=dbuf, counts=counts)
        # -- line 7/8: d_h (the only level-boundary sync) -> M_h
        d_h = float(d_dev)
        m_h = max(8, int(math.ceil(q2 * d_h)))
        if m_cap is not None:
            m_h = min(m_h, m_cap)
        mbuf = _bucket(m_h)
        # -- line 9/10: J_h ~ Multinomial(P_h, U_h), A_h weights
        centers, n_distinct = _bless_sample(
            k_j, cand_idx, s, wvec, tot, jnp.asarray(r_h, jnp.int32),
            jnp.asarray(m_h, jnp.int32), mbuf=mbuf, n=n)
        dbuf = _bucket(int(n_distinct))
        levels.append(BlessLevel(lam=lam_h, centers=centers, d_h=d_h, m_h=m_h, r_h=r_h))
    return BlessResult(levels=levels, lam_path=lams)


# =============================================================================
# Algorithm 2 — BLESS-R (rejection sampling, without replacement)
# =============================================================================


def _blessr_gates_impl(k_u, betas, n):
    """All H Bernoulli pre-filters (Alg. 2 lines 5-8) in one dispatch:
    per level the survivor-first index order and the survivor count — one
    host fetch of (H,) sizes instead of H gate/argsort round-trips."""
    global _LADDER_TRACES
    _LADDER_TRACES += 1
    h = betas.shape[0]
    gate = jax.random.uniform(k_u, (h, n)) < betas[:, None]
    r_vec = jnp.sum(gate, axis=1).astype(jnp.int32)
    orders = jnp.argsort(~gate, axis=1).astype(jnp.int32)  # survivors first
    return orders, r_vec


_blessr_gates = partial(jax.jit, static_argnames=("n",))(_blessr_gates_impl)


def _bucket32(x: int) -> int:
    """Finer (multiple-of-32) bucket for Alg. 2's *internal* center buffers.

    The per-level (M, M) factor + (R, M) quadform are so dbuf-sensitive
    that quarter-pow2 padding (up to 25% extra M) costs more wall time than
    the occasional extra recompile the finer grid admits. Public CenterSet
    buffers keep the coarse ``_bucket`` convention.
    """
    x = max(1, int(x))
    return _bucket(x) if x <= 32 else -(-x // 32) * 32


def _compact_body(u_idx, p, acc, m_h, *, mbuf, m_cap):
    """Compact acceptances into an (mbuf,) CenterSet; with ``m_cap`` keep
    the m_cap highest-probability acceptances (memory guard)."""
    m_h = jnp.asarray(m_h, jnp.int32)
    if m_cap is not None:
        keep = jnp.argsort(jnp.where(acc, -p, jnp.inf))[:m_cap]
        acc = jnp.zeros_like(acc).at[keep].set(True) & acc
        m_h = jnp.minimum(m_h, m_cap)
    sel = jnp.argsort(~acc)[:mbuf]
    j_mask = jnp.arange(mbuf) < m_h
    return CenterSet(
        idx=u_idx[sel].astype(jnp.int32),
        weight=jnp.where(j_mask, p[sel], 1.0).astype(jnp.float32),
        mask=j_mask,
        count=m_h,
    )


@partial(jax.jit, static_argnames=("mbuf", "m_cap"))
def _blessr_compact(u_idx, p, acc, m_h, *, mbuf, m_cap):
    """Standalone compaction — only the ladder's final level needs it (every
    other level's compaction is fused into the next level's dispatch)."""
    global _LADDER_TRACES
    _LADDER_TRACES += 1
    return _compact_body(u_idx, p, acc, m_h, mbuf=mbuf, m_cap=m_cap)


def _blessr_level_impl(k_a, x, kernel, order_h, pu, pp, pacc, pm, lam_prev,
                       beta, q2v, r_h, *, backend, rbuf, dbuf, m_cap,
                       identity_order):
    """One fused Alg. 2 level: pack the previous level's acceptances into
    its (dbuf,) center set J_{h-1}, then score + accept this level's
    candidates against it (lines 9-12) — a single dispatch per level, with
    the (m_h, sum s) statistics stacked so the driver blocks on exactly one
    2-float fetch.

    ``identity_order=True`` is the beta_h = 1 regime (every point survives
    the Bernoulli pre-filter): the survivor order is the identity, so the
    candidate gather is skipped entirely and rbuf == n.
    """
    global _LADDER_TRACES
    _LADDER_TRACES += 1
    n = x.shape[0]
    centers = _compact_body(pu, pp, pacc, pm, mbuf=dbuf, m_cap=m_cap)
    if identity_order:
        assert rbuf == n
        u_idx = jnp.arange(n, dtype=jnp.int32)
        x_cand = x
    else:
        u_idx = order_h[: min(rbuf, n)]
        if rbuf > n:
            u_idx = jnp.pad(u_idx, (0, rbuf - n))
        x_cand = x[u_idx]
    u_mask = jnp.arange(rbuf) < r_h
    lamn = lam_prev * n
    # Alg. 2 center sets are distinct (rejection sampling draws each j at
    # most once), so the Alg. 1 dedup pass is the identity here — score
    # straight against the padded set. An empty set degenerates cleanly:
    # an all-false mask zeroes the quadratic form, s = K_ii/(lam n).
    reg = jnp.where(centers.mask, lamn * centers.weight, 1.0)
    s = backend.rls_scores(kernel, x_cand, x[centers.idx], centers.mask,
                           reg, lamn)
    s = jnp.where(u_mask, jnp.clip(s, _SCORE_FLOOR, 1.0), 0.0)
    p = jnp.minimum(q2v * s, 1.0)
    # -- line 11: accept j with prob p_j / beta  (clipped: see App. C)
    acc = (jax.random.uniform(k_a, (rbuf,)) < jnp.minimum(p / beta, 1.0)) & u_mask
    stats = jnp.stack([jnp.sum(acc.astype(jnp.float32)), jnp.sum(s)])
    return centers, u_idx, p, acc, stats


_blessr_level = partial(jax.jit, static_argnames=(
    "backend", "rbuf", "dbuf", "m_cap", "identity_order"))(_blessr_level_impl)


def bless_r(
    key: Array,
    x: Array,
    kernel: Kernel,
    lam: float,
    *,
    q: float = 2.0,
    q2: float = 3.0,
    lam0: float | None = None,
    t: float = 1.0,
    m_cap: int | None = None,
    backend: BackendLike = None,
) -> BlessResult:
    """Bottom-up Leverage Score Sampling without replacement (paper Alg. 2).

    Per level h: a Bernoulli(beta_h) pre-filter plays the role of U_h
    (beta_h = min(q2 kappa^2 / (lam_h n), 1)); each survivor j is kept with
    probability p_{h,j}/beta_h where p_{h,j} = min(q2 * l~_{J_{h-1}}(x_j,
    lam_{h-1}), 1); kept columns get weight A_jj = p_{h,j}.

    The Bernoulli gates of every beta_h < 1 level are drawn in one jitted
    phase up front (one host fetch of the survivor counts; beta_h = 1 levels
    need no gate — everyone survives). Each level then runs exactly one
    fused dispatch (previous level's compaction + this level's score/accept)
    and blocks on exactly one 2-float statistics fetch.
    """
    n = x.shape[0]
    kap2 = float(kernel.kappa_sq)
    lam0 = kap2 / min(t, 1.0) if lam0 is None else lam0
    lams = lam_ladder(lam, lam0, q)
    backend = resolve_backend(backend, n=n)
    level_fn = _blessr_level if backend.jit_safe else _blessr_level_impl

    keys = jax.random.split(key, len(lams) + 1)
    betas_host = [min(q2 * kap2 / (lam_h * n), 1.0) for lam_h in lams]
    gated = [h for h, b in enumerate(betas_host) if b < 1.0]
    r_host = {h: n for h in range(len(lams))}
    if gated:
        orders, r_vec = _blessr_gates(
            keys[-1], jnp.asarray([betas_host[h] for h in gated], jnp.float32),
            n=n)
        r_host.update(zip(gated, np.asarray(r_vec).tolist()))
    row_of = {h: i for i, h in enumerate(gated)}
    no_order = jnp.zeros((0,), jnp.int32)  # beta = 1 levels take no gate order

    # prev = the not-yet-compacted acceptances of the last productive level;
    # the dispatch of level h packs them into J_{h-1} on-device, so
    # ``pending`` carries that level's metadata until its centers exist.
    prev = (jnp.zeros((1,), jnp.int32), jnp.ones((1,), jnp.float32),
            jnp.zeros((1,), bool), 0)
    pending: dict | None = None
    dbuf = 1
    levels: list[BlessLevel] = []
    lam_prev = lam0
    for h, lam_h in enumerate(lams):
        r_h = r_host[h]
        if r_h == 0:
            lam_prev = lam_h
            continue
        identity = betas_host[h] >= 1.0
        rbuf = n if identity else min(_bucket(r_h), n)
        order_h = no_order if identity else orders[row_of[h]]
        # -- lines 9-12: J_{h-1} pack + scores at lam_{h-1} + acceptances
        packed, u_idx, p, acc, stats = level_fn(
            keys[h], x, kernel, order_h, *prev, lam_prev, betas_host[h],
            q2, r_h, backend=backend, rbuf=rbuf, dbuf=dbuf, m_cap=m_cap,
            identity_order=identity)
        if pending is not None:
            levels.append(BlessLevel(centers=packed, **pending))
            pending = None
        stats = np.asarray(stats)  # the level's one blocking sync
        m_h = int(stats[0])
        d_h = float(n / r_h * stats[1])
        lam_prev = lam_h
        if m_h == 0:
            continue
        m_kept = m_h if m_cap is None else min(m_h, m_cap)
        prev = (u_idx, p, acc, m_h)
        pending = dict(lam=lam_h, d_h=d_h, m_h=m_kept, r_h=r_h)
        dbuf = _bucket32(m_kept)
    if pending is not None:  # final level: nothing left to fuse it into
        centers = _blessr_compact(*prev[:3], jnp.asarray(prev[3], jnp.int32),
                                  mbuf=_bucket(pending["m_h"]), m_cap=m_cap)
        levels.append(BlessLevel(centers=centers, **pending))
    return BlessResult(levels=levels, lam_path=lams)
