"""BLESS (Alg. 1) and BLESS-R (Alg. 2) — bottom-up leverage score sampling.

Faithful line-by-line implementations of the paper's Algorithms 1 and 2.
The ladder itself runs on the host (H ~ log(lam0/lam)/log q levels); every
level's heavy work (Gram blocks, Cholesky, Eq. 3 scoring, sampling) is a
jitted function on pow2-padded buffers, so the jit cache stays O(log) sized
and the arithmetic is within a factor ~2 of the unpadded cost.

Paper-vs-practice constants: Thm. 1's q1/q2 include union-bound log factors
that the paper's own experiments do not use (Sec. 4 reaches M ~ 1e4 centers
at n = 7e4). ``theory_constants(t, q, n, H, delta)`` reproduces Thm. 1's
values; the defaults are the practical ones used in our Fig. 1/2 analogues.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial

import jax
import jax.numpy as jnp

from .gram import BackendLike, Kernel, resolve_backend
from .leverage import CenterSet, approx_rls

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class BlessLevel:
    """One rung of the ladder: accurate scores at scale lam_h."""

    lam: float
    centers: CenterSet  # (J_h, A_h) on a padded buffer
    d_h: float  # n/R_h * sum of candidate scores  (≈ d_eff(lam_h))
    m_h: int  # |J_h|
    r_h: int  # |U_h|


@dataclasses.dataclass(frozen=True)
class BlessResult:
    levels: list[BlessLevel]
    lam_path: list[float]

    @property
    def final(self) -> BlessLevel:
        return self.levels[-1]

    def scores(self, kernel: Kernel, x_all: Array, lam: float | None = None,
               *, backend: BackendLike = None) -> Array:
        """Approximate leverage scores for every point at the final scale."""
        from .leverage import approx_rls_all

        lvl = self.final
        return approx_rls_all(kernel, x_all, lvl.centers, jnp.asarray(lam or lvl.lam),
                              backend=backend)


def theory_constants(t: float, q: float, n: int, h: int, delta: float = 0.1):
    """Thm. 1 (Alg. 1) constants: (q1, q2)."""
    q2 = 12.0 * q * (2 * t + 1) ** 2 / t**2 * (1 + t) * math.log(12 * h * n / delta)
    q1 = 5.0 * q2 / (q * (1 + t))
    return q1, q2


def lam_ladder(lam: float, lam0: float, q: float) -> list[float]:
    """Geometric ladder lam_0 > ... > lam_H = lam (lam_h = lam_{h-1}/q)."""
    h = max(1, math.ceil(math.log(lam0 / lam) / math.log(q)))
    lams = [lam0 / q**i for i in range(1, h)]
    lams.append(lam)  # pin the final level exactly at lam
    return lams


def _pow2(x: int) -> int:
    return 1 << max(0, (int(x) - 1)).bit_length()


# =============================================================================
# Algorithm 1 — BLESS (with replacement)
# =============================================================================


def bless(
    key: Array,
    x: Array,
    kernel: Kernel,
    lam: float,
    *,
    q: float = 2.0,
    q1: float = 3.0,
    q2: float = 3.0,
    lam0: float | None = None,
    t: float = 1.0,
    m_cap: int | None = None,
    backend: BackendLike = None,
) -> BlessResult:
    """Bottom-up Leverage Score Sampling (paper Alg. 1).

    Args:
      key: PRNG key.
      x: (n, d) dataset.
      kernel: bounded PSD kernel.
      lam: target regularization (the paper's lambda).
      q: ladder step (> 1).
      q1: candidate-set multiplier, R_h = q1 * min(kappa^2/lam_h, n).
      q2: center multiplier, M_h = q2 * d_h.
      lam0: ladder start; defaults to the paper's kappa^2/min(t, 1).
      t: target multiplicative accuracy (only sets the default lam0).
      m_cap: optional hard cap on M_h (memory guard for benchmarks).
      backend: kernel-operator backend for the Eq. 3 scorer — an instance,
        a registry name ("jnp" | "pallas" | "sharded"), or None for the
        platform heuristic (repro.core.backend.default_backend).

    Returns:
      BlessResult with one BlessLevel per rung — the whole regularization
      path {lam_h}, the paper's "computed at once" advantage.
    """
    n = x.shape[0]
    kap2 = float(kernel.kappa_sq)
    lam0 = kap2 / min(t, 1.0) if lam0 is None else lam0
    lams = lam_ladder(lam, lam0, q)
    backend = resolve_backend(backend, n=n)

    centers = CenterSet.empty(1)
    levels: list[BlessLevel] = []
    for lam_h in lams:
        key, k_u, k_j = jax.random.split(key, 3)
        # -- line 4/5: uniform candidates U_h, R_h = q1 * min(kappa^2/lam_h, n)
        r_h = max(8, int(math.ceil(q1 * min(kap2 / lam_h, n))))
        rbuf = _pow2(r_h)
        u_idx = jax.random.randint(k_u, (rbuf,), 0, n)
        u_mask = jnp.arange(rbuf) < r_h
        # -- line 6: Eq. 3 scores of candidates against (J_{h-1}, A_{h-1})
        s = approx_rls(kernel, x[u_idx], u_mask, x, centers, jnp.asarray(lam_h),
                       backend=backend)
        s = jnp.where(u_mask, s, 0.0)
        # -- line 7/8: sampling distribution and d_h
        tot = jnp.maximum(jnp.sum(s), 1e-30)
        p = s / tot
        d_h = float(n / r_h * tot)
        m_h = max(8, int(math.ceil(q2 * d_h)))
        if m_cap is not None:
            m_h = min(m_h, m_cap)
        mbuf = _pow2(m_h)
        # -- line 9: J_h ~ Multinomial(P_h, U_h), with replacement
        pos = _multinomial(k_j, p, mbuf)  # indices into the candidate buffer
        j_mask = jnp.arange(mbuf) < m_h
        # -- line 10: A_h = (R_h M_h / n) diag(p_{j_1}, ..., p_{j_M})
        w = jnp.where(j_mask, (r_h * m_h / n) * p[pos], 1.0)
        centers = CenterSet(
            idx=u_idx[pos].astype(jnp.int32),
            weight=w.astype(jnp.float32),
            mask=j_mask,
            count=jnp.asarray(m_h, jnp.int32),
        )
        levels.append(BlessLevel(lam=lam_h, centers=centers, d_h=d_h, m_h=m_h, r_h=r_h))
    return BlessResult(levels=levels, lam_path=lams)


@partial(jax.jit, static_argnames=("m",))
def _multinomial(key: Array, p: Array, m: int) -> Array:
    """m i.i.d. draws from categorical p via inverse-CDF on sorted uniforms."""
    cdf = jnp.cumsum(p)
    cdf = cdf / cdf[-1]
    u = jax.random.uniform(key, (m,))
    return jnp.searchsorted(cdf, u).astype(jnp.int32)


# =============================================================================
# Algorithm 2 — BLESS-R (rejection sampling, without replacement)
# =============================================================================


def bless_r(
    key: Array,
    x: Array,
    kernel: Kernel,
    lam: float,
    *,
    q: float = 2.0,
    q2: float = 3.0,
    lam0: float | None = None,
    t: float = 1.0,
    m_cap: int | None = None,
    backend: BackendLike = None,
) -> BlessResult:
    """Bottom-up Leverage Score Sampling without replacement (paper Alg. 2).

    Per level h: a Bernoulli(beta_h) pre-filter plays the role of U_h
    (beta_h = min(q2 kappa^2 / (lam_h n), 1)); each survivor j is kept with
    probability p_{h,j}/beta_h where p_{h,j} = min(q2 * l~_{J_{h-1}}(x_j,
    lam_{h-1}), 1); kept columns get weight A_jj = p_{h,j}.
    """
    n = x.shape[0]
    kap2 = float(kernel.kappa_sq)
    lam0 = kap2 / min(t, 1.0) if lam0 is None else lam0
    lams = lam_ladder(lam, lam0, q)
    backend = resolve_backend(backend, n=n)

    centers = CenterSet.empty(1)
    levels: list[BlessLevel] = []
    lam_prev = lam0
    for lam_h in lams:
        key, k_u, k_a = jax.random.split(key, 3)
        beta = min(q2 * kap2 / (lam_h * n), 1.0)
        # -- lines 5-8: U_h by Bernoulli(beta) over [n]
        u_gate = jax.random.uniform(k_u, (n,)) < beta
        r_h = int(jnp.sum(u_gate))
        if r_h == 0:
            lam_prev = lam_h
            continue
        rbuf = _pow2(r_h)
        order = jnp.argsort(~u_gate)  # survivors first, stable
        u_idx = jnp.pad(order, (0, max(0, rbuf - n)))[:rbuf].astype(jnp.int32)
        u_mask = jnp.arange(rbuf) < r_h
        # -- line 10: scores at the *previous* scale lam_{h-1}
        s = approx_rls(kernel, x[u_idx], u_mask, x, centers, jnp.asarray(lam_prev),
                       backend=backend)
        p = jnp.minimum(q2 * s, 1.0)
        # -- line 11: accept j with prob p_j / beta  (clipped: see App. C)
        acc = (jax.random.uniform(k_a, (rbuf,)) < jnp.minimum(p / beta, 1.0)) & u_mask
        m_h = int(jnp.sum(acc))
        if m_h == 0:
            lam_prev = lam_h
            continue
        if m_cap is not None and m_h > m_cap:
            # memory guard: keep the m_cap highest-probability acceptances
            keep = jnp.argsort(jnp.where(acc, -p, jnp.inf))[:m_cap]
            acc = jnp.zeros_like(acc).at[keep].set(True) & acc
            m_h = int(jnp.sum(acc))
        mbuf = _pow2(m_h)
        sel = jnp.argsort(~acc)[:mbuf]
        j_mask = jnp.arange(mbuf) < m_h
        centers = CenterSet(
            idx=u_idx[sel],
            weight=jnp.where(j_mask, p[sel], 1.0).astype(jnp.float32),
            mask=j_mask,
            count=jnp.asarray(m_h, jnp.int32),
        )
        d_h = float(n / r_h * jnp.sum(jnp.where(u_mask, s, 0.0)))
        levels.append(BlessLevel(lam=lam_h, centers=centers, d_h=d_h, m_h=m_h, r_h=r_h))
        lam_prev = lam_h
    return BlessResult(levels=levels, lam_path=lams)
