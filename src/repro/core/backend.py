"""Kernel-operator backends — the single seam for every hot contraction.

Four contractions dominate the paper's cost story (BLESS Alg. 1/2 levels,
the Eq. 3 scorer, FALKON's CG in Sec. 3, and serving-side predict):

  * ``gram_block``      — a K(X, Z) block (every ladder level, K_MM)
  * ``masked_quadform`` — Eq. 3's inner term  K_Ji^T (K_JJ + lam n A)^{-1} K_Ji
  * ``knm_quadratic`` / ``knm_t`` — the CG matvec K_nM^T K_nM v and its
    right-hand side K_nM^T y, never materializing K_nM
  * ``knm_matvec``      — K(X, Z) v, the predict / Nystrom-KRR forward pass
    (FalkonModel.predict, nystrom_krr, batched serving)

Each ``Backend`` serves all of them:

  * ``JnpBackend``     — pure-jnp streaming reference. jit-safe (its methods
    can be traced with the kernel bandwidth as a tracer), so it is the one
    used inside the jitted Eq. 3 scorer. Default on CPU.
  * ``PallasBackend``  — the fused Pallas TPU kernels under
    ``repro.kernels.{gram,quadform,falkon_matvec}``; interpret-mode off-TPU
    so CI exercises the exact production code path.
  * ``ShardedBackend`` — shard_map data-parallel over the local device mesh
    (``repro.core.distributed``); X rows sharded, (M, M) state replicated.

Backends are small frozen dataclasses: hashable (usable as static jit
arguments) and comparable by configuration, so the jit cache keys correctly.
Selection is by instance, by registry name ("jnp" | "pallas" | "sharded"),
or ``None`` for the ``default_backend()`` platform + problem-size heuristic
(overridable without code edits via the ``REPRO_BACKEND`` env var).
"""
from __future__ import annotations

import dataclasses
import functools
import os
import warnings
from typing import Callable, ClassVar

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from ..kernels.falkon_matvec import ops as falkon_ops
from ..kernels.gram import ops as gram_ops
from ..kernels.quadform import ops as quadform_ops
from ..kernels.rls_score import ops as rls_ops
from . import health
from .gram import (Kernel, blocked_cross, get_family, kernel_family_names,
                   register_backend)
from .leverage import _chol_with_jitter

Array = jax.Array
KnmQuadraticOp = Callable[[Array], Array]

# ---------------------------------------------------------------------------
# Block-size tables.
#
# jnp streamer: rows per lax.scan block — sized so a (block, m) Gram slab
# stays comfortably in cache (CPU) / HBM working set (accelerators).
# Pallas: (bn, bm) VMEM tiles by problem size; small problems take small
# tiles so interpret-mode CI isn't dominated by padding, large ones take the
# MXU-saturating 512x256 shape (working set ~< 4 MB at d <= 2048).
# ---------------------------------------------------------------------------

STREAM_BLOCK = {"cpu": 2048, "gpu": 8192, "tpu": 8192}

PALLAS_GRAM_TILES = ((1024, (128, 128)), (8192, (256, 256)), (None, (512, 256)))
PALLAS_QUADFORM_TILES = ((1024, (128, 128)), (8192, (256, 256)), (None, (256, 256)))
PALLAS_MATVEC_BN = ((4096, 256), (None, 512))

# Backend-selection thresholds. The baked-in defaults were measured with
# ``tools/autotune_backend.py`` (which sweeps each pair of backends over a
# row grid and reports the timing crossover) on the reference CPU container;
# rerun it on real hardware and either edit these or set the printed
# ``REPRO_*_MIN_ROWS`` env vars — the env always wins (read per call, so
# tests and deploys can flip them without reimports). docs/backends.md has
# the calibration recipe.
_PALLAS_MIN_ROWS = 256  # interpret-mode never crosses over off-TPU; on-TPU floor
_SHARD_MIN_ROWS = 1 << 15  # below this collective latency beats the split
_STREAM_MIN_ROWS = 1 << 21  # above this X (+ its Gram tiles) stops fitting HBM


def _threshold(env: str, default: int) -> int:
    """An autotuned threshold with its env override (empty/unset -> default)."""
    raw = os.environ.get(env, "").strip()
    return int(raw) if raw else default


def _pick(table, size: int):
    for threshold, value in table:
        if threshold is None or size <= threshold:
            return value
    raise AssertionError("table has no catch-all row")


def _kernel_params(kernel: Kernel) -> tuple[str, float]:
    """(kind, sigma) for the Pallas wrappers; sigma must be concrete here
    because the kernels bake the family's inv_scale into the compiled
    epilogue. The family itself is resolved from the ``repro.families``
    registry — an unknown name raises with every registered family listed,
    not a hard-coded subset."""
    get_family(kernel.name)  # enumerates the registry on typos
    try:
        return kernel.name, float(kernel.sigma)
    except (TypeError, jax.errors.ConcretizationTypeError) as e:
        raise ValueError(
            f"PallasBackend needs a concrete kernel bandwidth for the "
            f"{kernel.name!r} family (registered: {kernel_family_names()}); "
            "call it outside jit (the core entry points already do)"
        ) from e


# ---------------------------------------------------------------------------
# Protocol
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Backend:
    """Abstract kernel-operator backend (see module docstring)."""

    name: ClassVar[str] = "abstract"
    #: True if every method can be traced under jit with traced operands
    #: (including the kernel bandwidth). Non-jit-safe backends are driven by
    #: the host-level code paths instead.
    jit_safe: ClassVar[bool] = False

    def gram_block(self, kernel: Kernel, x: Array, z: Array) -> Array:
        """K(X, Z) of shape (n, m)."""
        raise NotImplementedError

    def masked_quadform(self, kernel: Kernel, x_cand: Array, z: Array,
                        mask: Array, reg: Array) -> Array:
        """q_i = K_Ji^T (K_JJ ∘ mask + diag(reg))^{-1} K_Ji for each candidate.

        ``z`` (Mbuf, d) are padded center coordinates, ``mask`` (Mbuf,) their
        validity, ``reg`` (Mbuf,) the regularized diagonal (lam n A on valid
        slots, 1 on padding). Returns (Rbuf,) in fp32 precision.
        """
        raise NotImplementedError

    def rls_scores(self, kernel: Kernel, x_cand: Array, z: Array,
                   z_mask: Array, reg: Array, lamn: Array) -> Array:
        """Eq. 3 scores  (K_ii - K_Ji^T (K_JJ + lam n A)^{-1} K_Ji) / (lam n)
        for each candidate row — the BLESS ladder's per-level contraction.

        ``z`` (Mbuf, d) padded centers, ``z_mask`` (Mbuf,) validity, ``reg``
        (Mbuf,) the regularized diagonal (lam n A on valid slots, 1 on
        padding), ``lamn`` the scalar lam * n. Returns (Rbuf,) fp32 scores
        (unclipped, unmasked — the ladder applies its own floor/candidate
        mask). The default composes ``masked_quadform`` with the family
        diagonal; backends override it to fuse the whole chain (Pallas keeps
        the (Rbuf, Mbuf) Gram tile in VMEM for its entire lifetime).
        """
        kdiag = kernel.diag(x_cand)
        quad = self.masked_quadform(kernel, x_cand, z, z_mask, reg)
        return (kdiag - quad) / lamn

    def knm_quadratic(self, kernel: Kernel, x: Array, z: Array, *,
                      mask: Array | None = None) -> KnmQuadraticOp:
        """Build the v -> K_nM^T (K_nM v) operator closure for CG.

        The returned op accepts a single fp32 vector (M,) or an (M, k)
        panel of CG iterates — the multi-RHS block-CG form. Panels reuse
        each streamed Gram block for every column, so extra right-hand
        sides cost GEMM flops, not extra kernel evaluations.

        ``mask`` — optional per-column row-exclusion weights, (n,) for a
        vector op or an (n, k) panel giving column j its own row subset
        (exact k-fold CV): column j computes ``K_nM^T diag(mask[:, j])
        K_nM v_j``, one extra elementwise multiply on the streamed
        (block, k) intermediate. ``mask=None`` is the original program
        bit-for-bit on every backend.
        """
        raise NotImplementedError

    def knm_t(self, kernel: Kernel, x: Array, z: Array, y: Array, *,
              mask: Array | None = None) -> Array:
        """K_nM^T y — the CG right-hand side(s).

        ``y`` is fp32 (n,) -> (M,), or an (n, k) target panel -> (M, k).
        ``mask`` (optional, shaped like ``y``) computes ``K_nM^T (mask *
        y)``; since it enters linearly, backends fold it into the targets
        up front (one elementwise multiply, no new streamed program).
        """
        raise NotImplementedError

    def knm_operators(self, kernel: Kernel, x: Array, z: Array,
                      y: Array, *,
                      mask: Array | None = None) -> tuple[KnmQuadraticOp, Array]:
        """Return (quadratic op, K_nM^T y) together.

        Lets backends that stage data (sharding, device placement) pay the
        staging cost once; ``y`` may be (n,) or an (n, k) panel, ``mask``
        an optional per-column row-exclusion panel applied to both halves
        (see ``knm_quadratic`` / ``knm_t``).
        """
        return (self.knm_quadratic(kernel, x, z, mask=mask),
                self.knm_t(kernel, x, z, y, mask=mask))

    def knm_matvec(self, kernel: Kernel, x: Array, z: Array, v: Array) -> Array:
        """K(X, Z) v — the predict / KRR forward contraction.

        ``v`` is fp32 (M,) -> (n,), or an (M, k) coefficient panel ->
        (n, k) (multi-output predict: one kernel evaluation for all k).
        """
        raise NotImplementedError


# ---------------------------------------------------------------------------
# jnp reference backend
# ---------------------------------------------------------------------------


def _quadform_from_chol(chol: Array, g: Array) -> Array:
    """rowsum(solve(L, g^T)^2) — q_i = g_i^T (L L^T)^{-1} g_i.

    Two algebraically identical strategies, picked by static shape: the
    triangular solve streams g through trsm (O(R M^2) at trsm throughput),
    while ``L^{-1}`` + GEMM pays one (M, M) triangular inversion to move the
    O(R M^2) bulk onto the GEMM path (~3-4x the trsm rate on the target
    container). Measured crossover: GEMM wins once R >= 3 M (inversion
    amortized) and M <= 768 (inversion itself still cheap); trsm elsewhere.
    """
    r, m = g.shape[0], chol.shape[0]
    if r >= 3 * m and m <= 768:
        inv_l = jax.scipy.linalg.solve_triangular(
            chol, jnp.eye(m, dtype=chol.dtype), lower=True)
        v = g @ inv_l.T  # (R, M) GEMM: rows v_i = L^{-1} g_i
        return jnp.sum(v * v, axis=1)
    v = jax.scipy.linalg.solve_triangular(chol, g.T, lower=True)
    return jnp.sum(v * v, axis=0)


@dataclasses.dataclass(frozen=True)
class JnpBackend(Backend):
    """Pure-jnp row-streaming backend (the numerical reference)."""

    name: ClassVar[str] = "jnp"
    jit_safe: ClassVar[bool] = True
    block: int | None = None  # stream rows per block; None -> platform table

    def _block(self) -> int:
        return self.block or STREAM_BLOCK.get(jax.default_backend(), 2048)

    def gram_block(self, kernel: Kernel, x: Array, z: Array) -> Array:
        """K(X, Z) (n, m) fp32, streamed in row blocks of ``_block()``."""
        return blocked_cross(kernel, x, z, block=self._block())

    def masked_quadform(self, kernel: Kernel, x_cand: Array, z: Array,
                        mask: Array, reg: Array) -> Array:
        """Eq. 3 quadratic form on the padded K_JJ; the solve strategy is
        picked from the static (R, M) shape by ``_quadform_from_chol``."""
        m = mask.astype(z.dtype)
        kjj = kernel.cross_unfused(z, z) * (m[:, None] * m[None, :]) + jnp.diag(reg)
        g = kernel.cross_unfused(x_cand, z) * m[None, :]
        chol = _chol_with_jitter(kjj)
        return _quadform_from_chol(chol, g)

    def knm_quadratic(self, kernel: Kernel, x: Array, z: Array, *,
                      mask: Array | None = None) -> KnmQuadraticOp:
        """CG quadratic op over the jnp row streamer ((M,) or (M, k));
        optional per-column row ``mask`` (exact-CV panels)."""
        from .falkon import local_knm_quadratic

        return local_knm_quadratic(kernel, x, z, block=self._block(), mask=mask)

    def knm_t(self, kernel: Kernel, x: Array, z: Array, y: Array, *,
              mask: Array | None = None) -> Array:
        """K_nM^T y, streamed; (n,) -> (M,) or panel (n, k) -> (M, k).
        ``mask`` folds into the targets (K_nM^T (mask * y))."""
        from .falkon import local_knm_t

        return local_knm_t(kernel, x, z, y, block=self._block(), mask=mask)

    def knm_matvec(self, kernel: Kernel, x: Array, z: Array, v: Array) -> Array:
        """K(X, Z) v, jitted streaming (serving hot path): one compiled
        call per (shapes, block); ``v`` (M,) -> (n,), (M, k) -> (n, k)."""
        return _jnp_knm_matvec(kernel, x, z, v, block=self._block())


@functools.partial(jax.jit, static_argnames=("block",))
def _jnp_knm_matvec(kernel: Kernel, x: Array, z: Array, v: Array, *,
                    block: int) -> Array:
    """K(X, Z) v, streaming X in row blocks — the jnp predict contraction.

    ``v`` (M,) or (M, k): each streamed Gram block is contracted against
    every column before being discarded.
    """
    n = x.shape[0]
    if n <= block:
        return kernel.cross(x, z) @ v
    pad = (-n) % block
    xp = jnp.pad(x, ((0, pad), (0, 0)))
    out = jax.lax.map(lambda xb: kernel.cross(xb, z) @ v,
                      xp.reshape(-1, block, x.shape[1]))
    return out.reshape((-1,) + v.shape[1:])[:n]


# ---------------------------------------------------------------------------
# Pallas fused-kernel backend
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PallasBackend(Backend):
    """Fused Pallas TPU kernels; interpret-mode anywhere without a TPU.

    ``bf16=True`` is the opt-in mixed-precision mode: every kernel's dominant
    MXU product loads its operands as bf16 and accumulates fp32 (the norms,
    exp epilogues, and second-stage contractions stay fp32). Roughly doubles
    MXU throughput and halves the tile working set on TPU; expect ~1e-2
    relative error on kernel values for unit-scale data (tolerances measured
    in tests/test_backend.py, documented in DESIGN.md §2).
    """

    name: ClassVar[str] = "pallas"
    interpret: bool | None = None  # None -> auto (off-TPU interprets)
    bn: int | None = None  # tile overrides; None -> size tables above
    bm: int | None = None
    bf16: bool = False  # mixed-precision MXU tiles (fp32 accumulation)

    def _gram_tiles(self, n: int, m: int) -> tuple[int, int]:
        bn, bm = _pick(PALLAS_GRAM_TILES, max(n, m))
        return self.bn or bn, self.bm or bm

    def gram_block(self, kernel: Kernel, x: Array, z: Array) -> Array:
        """K(X, Z) (n, m) fp32 from the fused Pallas gram kernel."""
        kind, sigma = _kernel_params(kernel)
        bn, bm = self._gram_tiles(x.shape[0], z.shape[0])
        return gram_ops.gram(x, z, sigma, kind=kind, bn=bn, bm=bm,
                             interpret=self.interpret, bf16=self.bf16)

    def masked_quadform(self, kernel: Kernel, x_cand: Array, z: Array,
                        mask: Array, reg: Array) -> Array:
        """Eq. 3 quadratic form: Pallas gram tiles + the fused quadform
        kernel consuming a dense (M, M) inverse (M ~ d_eff, cheap)."""
        m = mask.astype(x_cand.dtype)
        kjj = self.gram_block(kernel, z, z) * (m[:, None] * m[None, :]) + jnp.diag(reg)
        chol = _chol_with_jitter(kjj)
        # Explicit (M, M) inverse: the Pallas quadform consumes a dense W and
        # fuses rowsum((G W) * G) in VMEM; M ~ d_eff so the inverse is cheap.
        w = jax.scipy.linalg.cho_solve((chol, True), jnp.eye(kjj.shape[0], dtype=kjj.dtype))
        g = self.gram_block(kernel, x_cand, z) * m[None, :]
        bn, bm = self.bn or 0, self.bm or 0
        tbn, tbm = _pick(PALLAS_QUADFORM_TILES, max(g.shape))
        return quadform_ops.quadform(g, w, bn=bn or tbn, bm=bm or tbm,
                                     interpret=self.interpret, bf16=self.bf16)

    def rls_scores(self, kernel: Kernel, x_cand: Array, z: Array,
                   z_mask: Array, reg: Array, lamn: Array) -> Array:
        """Eq. 3 scores through the fused ``rls_score`` kernel: gram tile ->
        quadform -> score epilogue in one dispatch, the (Mbuf, Mbuf) inverse
        and centers VMEM-resident across the candidate grid. Falls back to
        the composed gram + quadform kernels past the VMEM budget."""
        if z.shape[0] > rls_ops.MAX_FUSED_M:
            return super().rls_scores(kernel, x_cand, z, z_mask, reg, lamn)
        kind, sigma = _kernel_params(kernel)
        m = z_mask.astype(x_cand.dtype)
        kjj = self.gram_block(kernel, z, z) * (m[:, None] * m[None, :]) + jnp.diag(reg)
        chol = _chol_with_jitter(kjj)
        w = jax.scipy.linalg.cho_solve(
            (chol, True), jnp.eye(kjj.shape[0], dtype=kjj.dtype))
        bn = self.bn or _pick(PALLAS_QUADFORM_TILES,
                              max(x_cand.shape[0], z.shape[0]))[0]
        return rls_ops.rls_score(x_cand, z, w, m, lamn, sigma, kind=kind,
                                 bn=bn, interpret=self.interpret, bf16=self.bf16)

    def _matvec_bn(self, n: int) -> int:
        return self.bn or _pick(PALLAS_MATVEC_BN, n)

    def knm_quadratic(self, kernel: Kernel, x: Array, z: Array, *,
                      mask: Array | None = None) -> KnmQuadraticOp:
        """CG quadratic op over the fused Pallas sweep; accepts (M,) or an
        (M, k) panel (one Gram tile per step serves every column). A
        ``mask`` panel rides the same grid as one extra VMEM multiply on
        the (bn, k) intermediate (the masked kernel variant)."""
        kind, sigma = _kernel_params(kernel)
        return falkon_ops.make_knm_quadratic_op(
            x, z, sigma, kind=kind, bn=self._matvec_bn(x.shape[0]),
            interpret=self.interpret, bf16=self.bf16, mask=mask)

    def knm_t(self, kernel: Kernel, x: Array, z: Array, y: Array, *,
              mask: Array | None = None) -> Array:
        """K_nM^T y fused in VMEM; (n,) -> (M,) or panel (n, k) -> (M, k).
        ``mask`` folds into the targets (K_nM^T (mask * y))."""
        kind, sigma = _kernel_params(kernel)
        return falkon_ops.knm_t(x, z, y, sigma, kind=kind,
                                bn=self._matvec_bn(x.shape[0]),
                                interpret=self.interpret, bf16=self.bf16,
                                mask=mask)

    def knm_matvec(self, kernel: Kernel, x: Array, z: Array, v: Array) -> Array:
        """K(X, Z) v fused in VMEM; (M,) -> (n,) or (M, k) -> (n, k)."""
        kind, sigma = _kernel_params(kernel)
        return falkon_ops.knm_matvec(x, z, v, sigma, kind=kind,
                                     bn=self._matvec_bn(x.shape[0]),
                                     interpret=self.interpret, bf16=self.bf16)


# ---------------------------------------------------------------------------
# shard_map data-parallel backend
# ---------------------------------------------------------------------------


def _sharded_gram_local(kernel: Kernel, xl: Array, z: Array) -> Array:
    return kernel.cross(xl, z)


def _sharded_quadform_local(kernel: Kernel, xc: Array, z: Array, m: Array,
                            chol: Array) -> Array:
    g = kernel.cross(xc, z) * m[None, :]
    v = jax.scipy.linalg.solve_triangular(chol, g.T, lower=True)
    return jnp.sum(v * v, axis=0)


@functools.lru_cache(maxsize=None)
def _sharded_gram_fn(mesh: Mesh, axis: str):
    """Jitted shard_map'd Gram, cached per (mesh, axis) so repeated calls at
    the same shapes reuse one compile (Mesh is hashable)."""
    return jax.jit(shard_map(
        _sharded_gram_local, mesh=mesh,
        in_specs=(P(), P(axis, None), P()), out_specs=P(axis, None)))


@functools.lru_cache(maxsize=None)
def _sharded_quadform_fn(mesh: Mesh, axis: str):
    return jax.jit(shard_map(
        _sharded_quadform_local, mesh=mesh,
        in_specs=(P(), P(axis, None), P(), P(), P()), out_specs=P(axis)))


@dataclasses.dataclass(frozen=True)
class ShardedBackend(Backend):
    """Data-parallel over the local device mesh: X rows sharded over ``axis``,
    (M, M) factors replicated, partials psum-ed (DESIGN.md §2)."""

    name: ClassVar[str] = "sharded"
    axis: str = "data"
    mesh: Mesh | None = None  # None -> 1-D mesh over all local devices

    def _mesh(self) -> Mesh:
        from .distributed import data_mesh

        return self.mesh if self.mesh is not None else data_mesh(self.axis)

    def gram_block(self, kernel: Kernel, x: Array, z: Array) -> Array:
        """K(X, Z) with X rows sharded over the mesh, Z replicated."""
        from .distributed import shard_rows

        mesh = self._mesh()
        xs = shard_rows(mesh, x, self.axis)
        return _sharded_gram_fn(mesh, self.axis)(kernel, xs, z)[: x.shape[0]]

    def masked_quadform(self, kernel: Kernel, x_cand: Array, z: Array,
                        mask: Array, reg: Array) -> Array:
        """Eq. 3 quadratic form: candidates row-sharded, the (Mbuf, Mbuf)
        Cholesky factor replicated (<= d_eff^2 by the paper's space bound)."""
        from .distributed import shard_rows

        mesh = self._mesh()
        m = mask.astype(x_cand.dtype)
        kjj = kernel.cross(z, z) * (m[:, None] * m[None, :]) + jnp.diag(reg)
        chol = _chol_with_jitter(kjj)  # replicated: (Mbuf, Mbuf) <= d_eff^2
        xs = shard_rows(mesh, x_cand, self.axis)
        quad = _sharded_quadform_fn(mesh, self.axis)(kernel, xs, z, m, chol)
        return quad[: x_cand.shape[0]]

    def knm_quadratic(self, kernel: Kernel, x: Array, z: Array, *,
                      mask: Array | None = None) -> KnmQuadraticOp:
        """CG quadratic op with X row-sharded and psum-ed (M,)/(M, k)
        partials — the collective schedule of a DP gradient all-reduce.
        A ``mask`` panel is row-sharded alongside X."""
        from .distributed import dist_knm_quadratic, shard_rows

        mesh = self._mesh()
        xs = shard_rows(mesh, x, self.axis)
        ms = None if mask is None else shard_rows(mesh, mask, self.axis)
        return dist_knm_quadratic(mesh, kernel, xs, z, x.shape[0], self.axis,
                                  mask=ms)

    def knm_t(self, kernel: Kernel, x: Array, z: Array, y: Array, *,
              mask: Array | None = None) -> Array:
        """K_nM^T y with X, y row-sharded; (n,) -> (M,), (n, k) -> (M, k).
        ``mask`` folds into the targets before sharding."""
        from .distributed import dist_knm_t, shard_rows

        if mask is not None:
            y = y * jnp.asarray(mask, y.dtype)
        mesh = self._mesh()
        return dist_knm_t(mesh, kernel, shard_rows(mesh, x, self.axis),
                          shard_rows(mesh, y, self.axis), z, x.shape[0], self.axis)

    def knm_operators(self, kernel: Kernel, x: Array, z: Array,
                      y: Array, *,
                      mask: Array | None = None) -> tuple[KnmQuadraticOp, Array]:
        """(quadratic op, K_nM^T y), staging X/y on device exactly once."""
        from .distributed import dist_knm_quadratic, dist_knm_t, shard_rows

        mesh = self._mesh()
        xs = shard_rows(mesh, x, self.axis)  # device_put once, reuse for both
        ym = y if mask is None else y * jnp.asarray(mask, y.dtype)
        ys = shard_rows(mesh, ym, self.axis)
        ms = None if mask is None else shard_rows(mesh, mask, self.axis)
        n = x.shape[0]
        return (dist_knm_quadratic(mesh, kernel, xs, z, n, self.axis, mask=ms),
                dist_knm_t(mesh, kernel, xs, ys, z, n, self.axis))

    def knm_matvec(self, kernel: Kernel, x: Array, z: Array, v: Array) -> Array:
        """K(X, Z) v, row-parallel (no collective); (M,) or (M, k) ``v``."""
        from .distributed import dist_knm_matvec, shard_rows

        mesh = self._mesh()
        return dist_knm_matvec(mesh, kernel, shard_rows(mesh, x, self.axis),
                               z, v, x.shape[0], self.axis)


# ---------------------------------------------------------------------------
# Guarded fallback backend (DESIGN.md §9)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class GuardedBackend(Backend):
    """Primary backend with automatic per-dispatch fallback to a reference.

    Wraps a ``primary`` (default: the fused Pallas kernels) and a
    numerically equivalent ``fallback`` (default: the jnp streamer). Every
    seam method tries the primary; a raised dispatch/compile failure is
    recorded in the health event log (``kind="backend_fallback"``), warned
    once per process per method, and the call is re-served by the fallback
    — one bad kernel dispatch degrades that call's *speed*, never the
    process. Registered as ``"guarded"`` so ``REPRO_BACKEND=guarded`` (or
    ``backend="guarded"``) hardens any entry point without code changes.

    Not jit-safe: the try/except needs the host, so fits through it take
    the host-driven CG path (the fallback leg would anyway — mixing traced
    primary dispatch with host recovery inside one jit cannot work).
    """

    name: ClassVar[str] = "guarded"
    jit_safe: ClassVar[bool] = False
    primary: Backend = dataclasses.field(default_factory=lambda: PallasBackend())
    fallback: Backend = dataclasses.field(default_factory=lambda: JnpBackend())

    def _guard(self, method: str, *args):
        try:
            return getattr(self.primary, method)(*args)
        except Exception as e:  # noqa: BLE001 — any dispatch failure falls back
            health.record_event("backend_fallback", method=method,
                                primary=self.primary.name,
                                fallback=self.fallback.name, error=repr(e))
            warnings.warn(
                f"{self.primary.name}.{method} dispatch failed ({e!r}); "
                f"falling back to {self.fallback.name}", RuntimeWarning,
                stacklevel=3)
            return getattr(self.fallback, method)(*args)

    def gram_block(self, kernel: Kernel, x: Array, z: Array) -> Array:
        """K(X, Z) via the primary, re-served by the fallback on failure."""
        return self._guard("gram_block", kernel, x, z)

    def masked_quadform(self, kernel: Kernel, x_cand: Array, z: Array,
                        mask: Array, reg: Array) -> Array:
        """Eq. 3 quadratic form with per-dispatch fallback."""
        return self._guard("masked_quadform", kernel, x_cand, z, mask, reg)

    def rls_scores(self, kernel: Kernel, x_cand: Array, z: Array,
                   z_mask: Array, reg: Array, lamn: Array) -> Array:
        """Eq. 3 scores with per-dispatch fallback."""
        return self._guard("rls_scores", kernel, x_cand, z, z_mask, reg, lamn)

    def knm_quadratic(self, kernel: Kernel, x: Array, z: Array, *,
                      mask: Array | None = None) -> KnmQuadraticOp:
        """CG quadratic op; both construction and every call are guarded."""
        try:
            op = self.primary.knm_quadratic(kernel, x, z, mask=mask)
        except Exception as e:  # noqa: BLE001
            health.record_event("backend_fallback", method="knm_quadratic",
                                primary=self.primary.name,
                                fallback=self.fallback.name, error=repr(e))
            return self.fallback.knm_quadratic(kernel, x, z, mask=mask)
        fb: list[KnmQuadraticOp | None] = [None]

        def guarded_op(v: Array) -> Array:
            try:
                return op(v)
            except Exception as e:  # noqa: BLE001
                health.record_event("backend_fallback", method="knm_quadratic",
                                    primary=self.primary.name,
                                    fallback=self.fallback.name, error=repr(e))
                if fb[0] is None:
                    fb[0] = self.fallback.knm_quadratic(kernel, x, z, mask=mask)
                return fb[0](v)

        return guarded_op

    def knm_t(self, kernel: Kernel, x: Array, z: Array, y: Array, *,
              mask: Array | None = None) -> Array:
        """K_nM^T y with per-dispatch fallback."""
        if mask is not None:
            y = y * jnp.asarray(mask, y.dtype)
        return self._guard("knm_t", kernel, x, z, y)

    def knm_matvec(self, kernel: Kernel, x: Array, z: Array, v: Array) -> Array:
        """K(X, Z) v (the serving contraction) with per-dispatch fallback."""
        return self._guard("knm_matvec", kernel, x, z, v)


# ---------------------------------------------------------------------------
# Selection
# ---------------------------------------------------------------------------


def _stream_backend() -> Backend:
    """Lazy ``StreamBackend`` factory — ``repro.stream`` imports this module,
    so the import has to happen at resolve time, not at module import."""
    from ..stream import StreamBackend

    return StreamBackend()


def default_backend(n: int | None = None) -> Backend:
    """Platform + problem-size heuristic.

    TPU -> fused Pallas kernels (compiled); multiple devices with enough rows
    to amortize the collectives -> shard_map; otherwise the jnp streamer —
    and past ``REPRO_STREAM_MIN_ROWS`` the pick is wrapped in the out-of-core
    ``StreamBackend`` (the chosen backend keeps building each tile, but X is
    streamed chunk-by-chunk instead of staged whole). ``n`` is the dataset
    row count when the caller knows it.

    The ``REPRO_BACKEND`` env var overrides the heuristic entirely — set it
    to a registry name ("jnp" | "pallas" | "sharded" | "stream" | ...) or a
    composite "stream:<inner>" spec to pin a backend on hardware runs
    without code edits ("auto"/"" fall through to the heuristic). The
    thresholds above are autotuned defaults (``tools/autotune_backend.py``);
    ``REPRO_PALLAS_MIN_ROWS`` / ``REPRO_SHARD_MIN_ROWS`` /
    ``REPRO_STREAM_MIN_ROWS`` override them per deployment.
    """
    env = os.environ.get("REPRO_BACKEND", "").strip().lower()
    if env and env != "auto":
        if ":" in env:
            from .gram import resolve_backend

            try:
                return resolve_backend(env)
            except ValueError as e:
                raise ValueError(f"REPRO_BACKEND={env!r}: {e}") from None
        try:
            return _ENV_BACKENDS[env]()
        except KeyError:
            raise ValueError(
                f"REPRO_BACKEND={env!r} is not a registered backend; "
                f"expected one of {sorted(_ENV_BACKENDS)} or 'auto'"
            ) from None
    platform = jax.default_backend()
    picked: Backend | None = None
    if platform == "tpu" and (n is None or n >= _threshold(
            "REPRO_PALLAS_MIN_ROWS", _PALLAS_MIN_ROWS)):
        picked = PallasBackend()
    elif (len(jax.devices()) > 1 and n is not None
          and n >= _threshold("REPRO_SHARD_MIN_ROWS", _SHARD_MIN_ROWS)):
        picked = ShardedBackend()
    else:
        picked = JnpBackend()
    if n is not None and n >= _threshold("REPRO_STREAM_MIN_ROWS",
                                         _STREAM_MIN_ROWS):
        from ..stream import StreamBackend

        return StreamBackend(inner=picked)
    return picked


_ENV_BACKENDS: dict[str, Callable[[], Backend]] = {
    "jnp": JnpBackend, "pallas": PallasBackend, "sharded": ShardedBackend,
    "guarded": GuardedBackend, "stream": _stream_backend,
}

register_backend("jnp", JnpBackend)
register_backend("pallas", PallasBackend)
register_backend("sharded", ShardedBackend)
register_backend("guarded", GuardedBackend)
register_backend("stream", _stream_backend)
