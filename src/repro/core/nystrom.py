"""Direct Nystrom-KRR solver (paper Def. 4) and exact KRR — test oracles.

    alpha = (K_nM^T K_nM + lam n K_MM)^+ K_nM^T y        (Def. 4)
    c     = (K + lam n I)^{-1} y                          (Eq. 12, exact KRR)

Both are O(n M^2) / O(n^3) dense solves; FALKON's CG must converge to the
Def. 4 solution, which is what tests/test_falkon.py asserts.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .falkon import FalkonModel
from .gram import Kernel
from .leverage import _chol_with_jitter, _psd_solve

Array = jax.Array


def nystrom_krr(kernel: Kernel, x: Array, y: Array, centers: Array, lam: float) -> FalkonModel:
    n = x.shape[0]
    knm = kernel.cross(x, centers)
    kmm = kernel.cross(centers, centers)
    h = knm.T @ knm + lam * n * kmm
    alpha = _psd_solve(h, knm.T @ y)
    return FalkonModel(centers=centers, alpha=alpha, kernel=kernel)


def exact_krr(kernel: Kernel, x: Array, y: Array, lam: float) -> FalkonModel:
    n = x.shape[0]
    k = kernel.gram(x)
    chol = _chol_with_jitter(k + lam * n * jnp.eye(n, dtype=k.dtype))
    c = jax.scipy.linalg.cho_solve((chol, True), y)
    return FalkonModel(centers=x, alpha=c, kernel=kernel)
