"""Direct Nystrom-KRR solver (paper Def. 4) and exact KRR — test oracles.

    alpha = (K_nM^T K_nM + lam n K_MM)^+ K_nM^T y        (Def. 4)
    c     = (K + lam n I)^{-1} y                          (Eq. 12, exact KRR)

Both are O(n M^2) / O(n^3) dense solves; FALKON's CG must converge to the
Def. 4 solution, which is what tests/test_falkon.py asserts. The K_nM
contractions route through the kernel-operator ``Backend`` seam so the
oracles run on whatever hardware path the estimators use; the returned
models also predict through the seam.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .falkon import FalkonModel
from .gram import BackendLike, Kernel, resolve_backend
from .leverage import _chol_with_jitter, _psd_solve

Array = jax.Array


def nystrom_krr(kernel: Kernel, x: Array, y: Array, centers: Array, lam: float,
                *, backend: BackendLike = None) -> FalkonModel:
    """Def. 4 direct solve; ``y`` may be (n,) or (n, k) (multi-output shares
    the factorization — only the K_nM^T y right-hand sides differ)."""
    n = x.shape[0]
    be = resolve_backend(backend, n=n)
    knm = be.gram_block(kernel, x, centers)
    kmm = be.gram_block(kernel, centers, centers)
    h = knm.T @ knm + lam * n * kmm
    # knm is already materialized: K_nM^T y is one matmul on it, exact for
    # (n,) and (n, k) alike — no second pass over the kernel evaluations.
    alpha = _psd_solve(h, knm.T @ y)
    return FalkonModel(centers=centers, alpha=alpha, kernel=kernel, backend=be)


def exact_krr(kernel: Kernel, x: Array, y: Array, lam: float,
              *, backend: BackendLike = None) -> FalkonModel:
    """Eq. 12 exact solve; multi-output ``y`` (n, k) rides the same Cholesky."""
    n = x.shape[0]
    be = resolve_backend(backend, n=n)
    k = be.gram_block(kernel, x, x)
    chol = _chol_with_jitter(k + lam * n * jnp.eye(n, dtype=k.dtype))
    c = jax.scipy.linalg.cho_solve((chol, True), y)
    return FalkonModel(centers=x, alpha=c, kernel=kernel, backend=be)
