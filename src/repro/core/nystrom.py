"""Direct Nystrom-KRR solver (paper Def. 4) and exact KRR — test oracles.

    alpha = (K_nM^T K_nM + lam n K_MM)^+ K_nM^T y        (Def. 4)
    c     = (K + lam n I)^{-1} y                          (Eq. 12, exact KRR)

Both are O(n M^2) / O(n^3) dense solves; FALKON's CG must converge to the
Def. 4 solution, which is what tests/test_falkon.py asserts. The K_nM
contractions route through the kernel-operator ``Backend`` seam so the
oracles run on whatever hardware path the estimators use; the returned
models also predict through the seam.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..testing import faults
from . import health
from .falkon import FalkonModel
from .gram import BackendLike, Kernel, resolve_backend

Array = jax.Array


def nystrom_krr(kernel: Kernel, x: Array, y: Array, centers: Array, lam: float,
                *, backend: BackendLike = None) -> FalkonModel:
    """Def. 4 direct solve; ``y`` may be (n,) or (n, k) (multi-output shares
    the factorization — only the K_nM^T y right-hand sides differ).

    This path runs eagerly and materializes its result, so the §9 health
    fences are always armed here: the escalating-jitter Cholesky ladder
    either factors H or raises ``health.FactorizationError``, and the
    returned alpha passes a finite-output fence — never a silent NaN. It
    also hosts the chaos harness's ``kmm.indefinite`` injection point.
    """
    n = x.shape[0]
    be = resolve_backend(backend, n=n)
    knm = be.gram_block(kernel, x, centers)
    kmm = be.gram_block(kernel, centers, centers)
    if faults.active():
        kmm = faults.corrupt("kmm.indefinite", kmm)
    h = knm.T @ knm + lam * n * kmm
    # knm is already materialized: K_nM^T y is one matmul on it, exact for
    # (n,) and (n, k) alike — no second pass over the kernel evaluations.
    chol, _ = health.safe_cholesky(h, what="Nystrom-KRR H = KnM^T KnM + lam n K_MM")
    alpha = jax.scipy.linalg.cho_solve((chol, True), knm.T @ y)
    health.check_finite(alpha, "nystrom_krr alpha")
    return FalkonModel(centers=centers, alpha=alpha, kernel=kernel, backend=be,
                       lam=float(lam), n_train=n)


def exact_krr(kernel: Kernel, x: Array, y: Array, lam: float,
              *, backend: BackendLike = None) -> FalkonModel:
    """Eq. 12 exact solve; multi-output ``y`` (n, k) rides the same Cholesky.

    Fenced like ``nystrom_krr``: the jitter ladder factors K + lam n I or
    raises, and the coefficients pass a finite-output fence.
    """
    n = x.shape[0]
    be = resolve_backend(backend, n=n)
    k = be.gram_block(kernel, x, x)
    chol, _ = health.safe_cholesky(k + lam * n * jnp.eye(n, dtype=k.dtype),
                                   what="exact-KRR K + lam n I")
    c = jax.scipy.linalg.cho_solve((chol, True), y)
    health.check_finite(c, "exact_krr alpha")
    return FalkonModel(centers=x, alpha=c, kernel=kernel, backend=be,
                       lam=float(lam), n_train=n)
