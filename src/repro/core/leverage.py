"""Ridge leverage scores: exact (Eq. 1) and Nystrom-approximate (Eq. 3).

All approximate-score paths run on *padded* center buffers with validity
masks so every ladder level of BLESS hits a bounded set of jit shapes
(pow2 buckets), which is what makes the host-orchestrated ladder cheap.

The Eq. 3 inner contraction (the K_Ji quadratic form) goes through the
kernel-operator ``Backend`` seam (``repro.core.backend``): jit-safe backends
(the jnp streamer) run inside one jitted scorer; the Pallas / shard_map
backends are driven by an equivalent host-level path because their tile and
collective schedules need concrete kernel parameters.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from .gram import BackendLike, Kernel, resolve_backend
from .health import chol_with_jitter_ladder

_SCORE_FLOOR = 1e-12  # keep sampling probabilities strictly positive


class CenterSet(NamedTuple):
    """A weighted Nystrom center set (J, A) on a padded buffer.

    idx:    (Mbuf,) int32 indices into [n]; arbitrary on invalid slots.
    weight: (Mbuf,) float  diag(A) of the paper's weight matrix A; 1 on
            invalid slots (keeps the padded K_JJ + lam*n*A well conditioned).
    mask:   (Mbuf,) bool   validity.
    count:  ()      int32  number of valid centers (|J|).
    """

    idx: jax.Array
    weight: jax.Array
    mask: jax.Array
    count: jax.Array

    @staticmethod
    def empty(mbuf: int) -> "CenterSet":
        return CenterSet(
            idx=jnp.zeros((mbuf,), jnp.int32),
            weight=jnp.ones((mbuf,), jnp.float32),
            mask=jnp.zeros((mbuf,), bool),
            count=jnp.asarray(0, jnp.int32),
        )


def exact_rls(kernel: Kernel, x: jax.Array, lam: float) -> jax.Array:
    """Exact ridge leverage scores  l(i, lam) = [K (K + lam n I)^{-1}]_ii.

    O(n^3) — the oracle everything else is measured against (Eq. 1).
    Uses diag((K + lam n I)^{-1} K) = diag of the PSD solve, via Cholesky.
    """
    n = x.shape[0]
    k = kernel.gram(x)
    s = _psd_solve(k + lam * n * jnp.eye(n, dtype=k.dtype), k)
    return jnp.clip(jnp.diagonal(s), _SCORE_FLOOR, 1.0)


def effective_dim(kernel: Kernel, x: jax.Array, lam: float) -> jax.Array:
    """d_eff(lam) = sum_i l(i, lam)."""
    return jnp.sum(exact_rls(kernel, x, lam))


def approx_rls(
    kernel: Kernel,
    x_cand: jax.Array,
    cand_mask: jax.Array,
    x_all: jax.Array,
    centers: CenterSet,
    lam: jax.Array,
    *,
    backend: BackendLike = None,
) -> jax.Array:
    """Approximate leverage scores (Eq. 3) of candidates against (J, A).

      l~_J(i, lam) = (lam n)^{-1} (K_ii - K_Ji^T (K_JJ + lam n A)^{-1} K_Ji)

    n is the *full* dataset size (x_all.shape[0]); candidates/centers live on
    padded buffers with masks. Invalid centers are neutralized by zeroing
    their Gram rows/cols and pinning the regularized diagonal to 1.
    Returns (Rbuf,) scores; entries at invalid candidates are _SCORE_FLOOR.
    """
    backend = resolve_backend(backend, n=x_all.shape[0])
    lam = jnp.asarray(lam)
    if backend.jit_safe:
        return _approx_rls_traced(kernel, x_cand, cand_mask, x_all, centers, lam, backend)
    return _approx_rls_host(backend, kernel, x_cand, cand_mask, x_all, centers, lam)


@partial(jax.jit, static_argnames=("backend",))
def _approx_rls_traced(kernel, x_cand, cand_mask, x_all, centers, lam, backend):
    """One jitted Eq. 3 scorer for jit-safe backends (bounded retrace set)."""
    n = x_all.shape[0]
    z = x_all[centers.idx]  # (Mbuf, d)

    def no_centers(_):
        return kernel.diag(x_cand) / (lam * n)

    def with_centers(_):
        reg = jnp.where(centers.mask, lam * n * centers.weight, 1.0)
        return backend.rls_scores(kernel, x_cand, z, centers.mask, reg, lam * n)

    scores = jax.lax.cond(centers.count > 0, with_centers, no_centers, None)
    scores = jnp.clip(scores, _SCORE_FLOOR, 1.0)
    return jnp.where(cand_mask, scores, _SCORE_FLOOR)


def _approx_rls_host(backend, kernel, x_cand, cand_mask, x_all, centers, lam):
    """Host-driven Eq. 3 for backends whose dispatch needs concrete values
    (Pallas tile params, shard_map staging). Same math as the traced path."""
    n = x_all.shape[0]
    if int(centers.count) > 0:
        z = x_all[centers.idx]
        reg = jnp.where(centers.mask, lam * n * centers.weight, 1.0)
        scores = backend.rls_scores(kernel, x_cand, z, centers.mask, reg, lam * n)
    else:
        scores = kernel.diag(x_cand) / (lam * n)
    scores = jnp.clip(scores, _SCORE_FLOOR, 1.0)
    return jnp.where(cand_mask, scores, _SCORE_FLOOR)


def approx_rls_all(
    kernel: Kernel,
    x_all: jax.Array,
    centers: CenterSet,
    lam: jax.Array,
    *,
    block: int = 4096,
    backend: BackendLike = None,
) -> jax.Array:
    """Eq. 3 scores for every i in [n], blocked over rows (used by Fig. 1)."""
    backend = resolve_backend(backend, n=x_all.shape[0])
    lam = jnp.asarray(lam)
    if backend.jit_safe:
        return _approx_rls_all_traced(kernel, x_all, centers, lam,
                                      block=block, backend=backend)
    n = x_all.shape[0]
    out = []
    for i in range(0, n, block):
        xb = x_all[i:i + block]
        mb = jnp.ones((xb.shape[0],), bool)
        out.append(_approx_rls_host(backend, kernel, xb, mb, x_all, centers, lam))
    return jnp.concatenate(out) if len(out) > 1 else out[0]


@partial(jax.jit, static_argnames=("block", "backend"))
def _approx_rls_all_traced(kernel, x_all, centers, lam, *, block, backend):
    n = x_all.shape[0]
    pad = (-n) % block
    xp = jnp.pad(x_all, ((0, pad), (0, 0)))
    maskp = jnp.arange(n + pad) < n

    def body(args):
        xb, mb = args
        return _approx_rls_traced(kernel, xb, mb, x_all, centers, lam, backend)

    out = jax.lax.map(body, (xp.reshape(-1, block, x_all.shape[1]), maskp.reshape(-1, block)))
    return out.reshape(-1)[:n]


def uniform_center_set(idx: jax.Array, n: int, mbuf: int) -> CenterSet:
    """Uniformly sampled centers J with the A = (|J|/n) I convention.

    With this weighting, Eq. 3 becomes the standard Nystrom RLS estimate
    (K_JJ + lam |J| I)^{-1} — see DESIGN.md §2 / Prop. 1 of the paper.
    """
    m = idx.shape[0]
    assert m <= mbuf
    pad = mbuf - m
    return CenterSet(
        idx=jnp.pad(idx.astype(jnp.int32), (0, pad)),
        weight=jnp.pad(jnp.full((m,), m / n, jnp.float32), (0, pad), constant_values=1.0),
        mask=jnp.arange(mbuf) < m,
        count=jnp.asarray(m, jnp.int32),
    )


# ---------------------------------------------------------------------------


def _chol_with_jitter(a: jax.Array) -> jax.Array:
    """Cholesky with escalating trace-scaled jitter for fp32 robustness.

    Now the §9 health ladder (``core/health.py``): jitter ``eps * 10^k``
    escalated under a ``lax.while_loop``, so the common path pays one
    Cholesky and retries are only *computed* on NaN. (Safe here: the
    blocked scorers map over rows with ``lax.map``/scan, not vmap.)
    Callers that want the jitter level reported (or the NaN-exhaustion
    fence armed) use ``health.chol_with_jitter_ladder`` /
    ``health.safe_cholesky`` directly.
    """
    chol, _ = chol_with_jitter_ladder(a)
    return chol


def _psd_solve(a: jax.Array, b: jax.Array) -> jax.Array:
    chol = _chol_with_jitter(a)
    y = jax.scipy.linalg.solve_triangular(chol, b, lower=True)
    return jax.scipy.linalg.solve_triangular(chol.T, y, lower=False)
