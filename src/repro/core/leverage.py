"""Ridge leverage scores: exact (Eq. 1) and Nystrom-approximate (Eq. 3).

All approximate-score paths run on *padded* center buffers with validity
masks so every ladder level of BLESS hits a bounded set of jit shapes
(pow2 buckets), which is what makes the host-orchestrated ladder cheap.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from .gram import Kernel

_SCORE_FLOOR = 1e-12  # keep sampling probabilities strictly positive


class CenterSet(NamedTuple):
    """A weighted Nystrom center set (J, A) on a padded buffer.

    idx:    (Mbuf,) int32 indices into [n]; arbitrary on invalid slots.
    weight: (Mbuf,) float  diag(A) of the paper's weight matrix A; 1 on
            invalid slots (keeps the padded K_JJ + lam*n*A well conditioned).
    mask:   (Mbuf,) bool   validity.
    count:  ()      int32  number of valid centers (|J|).
    """

    idx: jax.Array
    weight: jax.Array
    mask: jax.Array
    count: jax.Array

    @staticmethod
    def empty(mbuf: int) -> "CenterSet":
        return CenterSet(
            idx=jnp.zeros((mbuf,), jnp.int32),
            weight=jnp.ones((mbuf,), jnp.float32),
            mask=jnp.zeros((mbuf,), bool),
            count=jnp.asarray(0, jnp.int32),
        )


def exact_rls(kernel: Kernel, x: jax.Array, lam: float) -> jax.Array:
    """Exact ridge leverage scores  l(i, lam) = [K (K + lam n I)^{-1}]_ii.

    O(n^3) — the oracle everything else is measured against (Eq. 1).
    Uses diag((K + lam n I)^{-1} K) = diag of the PSD solve, via Cholesky.
    """
    n = x.shape[0]
    k = kernel.gram(x)
    s = _psd_solve(k + lam * n * jnp.eye(n, dtype=k.dtype), k)
    return jnp.clip(jnp.diagonal(s), _SCORE_FLOOR, 1.0)


def effective_dim(kernel: Kernel, x: jax.Array, lam: float) -> jax.Array:
    """d_eff(lam) = sum_i l(i, lam)."""
    return jnp.sum(exact_rls(kernel, x, lam))


@jax.jit
def approx_rls(
    kernel: Kernel,
    x_cand: jax.Array,
    cand_mask: jax.Array,
    x_all: jax.Array,
    centers: CenterSet,
    lam: jax.Array,
) -> jax.Array:
    """Approximate leverage scores (Eq. 3) of candidates against (J, A).

      l~_J(i, lam) = (lam n)^{-1} (K_ii - K_Ji^T (K_JJ + lam n A)^{-1} K_Ji)

    n is the *full* dataset size (x_all.shape[0]); candidates/centers live on
    padded buffers with masks. Invalid centers are neutralized by zeroing
    their Gram rows/cols and pinning the regularized diagonal to 1.
    Returns (Rbuf,) scores; entries at invalid candidates are _SCORE_FLOOR.
    """
    n = x_all.shape[0]
    z = x_all[centers.idx]  # (Mbuf, d)
    kdiag = kernel.diag(x_cand)

    def no_centers(_):
        return kdiag / (lam * n)

    def with_centers(_):
        m = centers.mask.astype(x_all.dtype)
        kjj = kernel.cross(z, z) * (m[:, None] * m[None, :])
        reg = jnp.where(centers.mask, lam * n * centers.weight, 1.0)
        kjj = kjj + jnp.diag(reg)
        g = kernel.cross(x_cand, z) * m[None, :]  # (Rbuf, Mbuf)
        chol = _chol_with_jitter(kjj)
        v = jax.scipy.linalg.solve_triangular(chol, g.T, lower=True)  # (Mbuf, Rbuf)
        quad = jnp.sum(v * v, axis=0)
        return (kdiag - quad) / (lam * n)

    scores = jax.lax.cond(centers.count > 0, with_centers, no_centers, None)
    scores = jnp.clip(scores, _SCORE_FLOOR, 1.0)
    return jnp.where(cand_mask, scores, _SCORE_FLOOR)


@partial(jax.jit, static_argnames=("block",))
def approx_rls_all(
    kernel: Kernel,
    x_all: jax.Array,
    centers: CenterSet,
    lam: jax.Array,
    *,
    block: int = 4096,
) -> jax.Array:
    """Eq. 3 scores for every i in [n], blocked over rows (used by Fig. 1)."""
    n = x_all.shape[0]
    pad = (-n) % block
    xp = jnp.pad(x_all, ((0, pad), (0, 0)))
    maskp = jnp.arange(n + pad) < n

    def body(args):
        xb, mb = args
        return approx_rls(kernel, xb, mb, x_all, centers, lam)

    out = jax.lax.map(body, (xp.reshape(-1, block, x_all.shape[1]), maskp.reshape(-1, block)))
    return out.reshape(-1)[:n]


def uniform_center_set(idx: jax.Array, n: int, mbuf: int) -> CenterSet:
    """Uniformly sampled centers J with the A = (|J|/n) I convention.

    With this weighting, Eq. 3 becomes the standard Nystrom RLS estimate
    (K_JJ + lam |J| I)^{-1} — see DESIGN.md §2 / Prop. 1 of the paper.
    """
    m = idx.shape[0]
    assert m <= mbuf
    pad = mbuf - m
    return CenterSet(
        idx=jnp.pad(idx.astype(jnp.int32), (0, pad)),
        weight=jnp.pad(jnp.full((m,), m / n, jnp.float32), (0, pad), constant_values=1.0),
        mask=jnp.arange(mbuf) < m,
        count=jnp.asarray(m, jnp.int32),
    )


# ---------------------------------------------------------------------------


def _chol_with_jitter(a: jax.Array) -> jax.Array:
    """Cholesky with a trace-scaled jitter retry for fp32 robustness."""
    eps = 1e-6 * jnp.mean(jnp.diagonal(a))
    chol = jnp.linalg.cholesky(a + eps * jnp.eye(a.shape[0], dtype=a.dtype))
    bad = jnp.any(jnp.isnan(chol))
    chol2 = jnp.linalg.cholesky(a + (1e3 * eps) * jnp.eye(a.shape[0], dtype=a.dtype))
    return jnp.where(bad, chol2, chol)


def _psd_solve(a: jax.Array, b: jax.Array) -> jax.Array:
    chol = _chol_with_jitter(a)
    y = jax.scipy.linalg.solve_triangular(chol, b, lower=True)
    return jax.scipy.linalg.solve_triangular(chol.T, y, lower=False)
