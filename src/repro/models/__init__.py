from .config import ArchConfig
from .model import (cache_specs, decode_step, forward, init_cache, init_params,
                    loss_fn, logits_fn, padded_vocab, param_specs)

__all__ = ["ArchConfig", "cache_specs", "decode_step", "forward", "init_cache",
           "init_params", "loss_fn", "logits_fn", "padded_vocab", "param_specs"]
