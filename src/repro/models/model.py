"""Model assembly: init, sharding specs, forward, loss, decode.

One generic stack covers all ten assigned archs via ArchConfig:
  * layers are grouped into period-patterns (Jamba: 8-layer groups of
    7 mamba + 1 attn, MoE on odd layers) and scanned over groups with
    stacked params + remat — HLO stays O(period) regardless of depth.
  * q-heads are padded to a multiple of the model axis where needed
    (DESIGN.md §5); padded heads are masked before o_proj, which keeps the
    function exactly equal to the unpadded model while remaining shardable.
  * vocab is padded to a multiple of 128; padded logits are masked in the
    chunked cross-entropy.

Params and caches are plain nested dicts; ``param_specs``/``cache_specs``
mirror their structure with PartitionSpecs by leaf-name rules.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..sharding.rules import MeshCtx, logical_to_spec
from .attention import attention, decode_attention, nystrom_attention
from .config import ArchConfig
from .layers import (apply_mrope, apply_rope, lowp, mlp_apply, mlp_init,
                     ninit, rms_norm, sinusoidal_pos)
from .mamba2 import mamba_block, mamba_decode, mamba_init
from .moe import moe_apply, moe_init

Array = jax.Array
TP = 16  # model-axis width of the production mesh


@jax.custom_jvp
def _opt_barrier(x: Array) -> Array:
    """optimization_barrier with a differentiation rule.

    The jax in this toolchain has no JVP for the raw primitive; the barrier
    is a scheduling fence only, so the tangent passes through untouched
    (matching the rule later jax versions ship).
    """
    return jax.lax.optimization_barrier(x)


@_opt_barrier.defjvp
def _opt_barrier_jvp(primals, tangents):
    (x,), (t,) = primals, tangents
    return _opt_barrier(x), t


def padded_vocab(cfg: ArchConfig) -> int:
    return (cfg.vocab_size + 127) // 128 * 128


def _dtype(cfg: ArchConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


# =============================================================================
# init
# =============================================================================


def _attn_init(key: Array, cfg: ArchConfig, dtype) -> dict:
    hp = cfg.padded_heads(TP)
    kvp = hp if cfg.n_kv_heads == cfg.n_heads else cfg.n_kv_heads  # pad MHA kv too
    ks = jax.random.split(key, 6)
    p = {
        "wq": ninit(ks[0], (cfg.d_model, hp * cfg.head_dim), dtype=dtype),
        "wk": ninit(ks[1], (cfg.d_model, kvp * cfg.head_dim), dtype=dtype),
        "wv": ninit(ks[2], (cfg.d_model, kvp * cfg.head_dim), dtype=dtype),
        "wo": ninit(ks[3], (hp * cfg.head_dim, cfg.d_model), dtype=dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((cfg.head_dim,), dtype)
        p["k_norm"] = jnp.zeros((cfg.head_dim,), dtype)
    return p


def _block_init(key: Array, cfg: ArchConfig, j: int, dtype) -> dict:
    kmix, kmlp, k3 = jax.random.split(key, 3)
    p: dict[str, Any] = {"ln_mix": jnp.zeros((cfg.d_model,), dtype)}
    if cfg.mixer_kind(j) == "attn":
        p["attn"] = _attn_init(kmix, cfg, dtype)
    else:
        p["mamba"] = mamba_init(kmix, cfg, dtype)
    kind = cfg.mlp_kind(j)
    if kind != "none":
        p["ln_mlp"] = jnp.zeros((cfg.d_model,), dtype)
        if kind == "moe":
            p["moe"] = moe_init(kmlp, cfg.d_model, cfg.d_ff, cfg.n_experts, cfg.mlp_act,
                                cfg.shared_expert_ff, dtype)
        else:
            p["mlp"] = mlp_init(kmlp, cfg.d_model, cfg.d_ff, cfg.mlp_act, dtype)
    return p


def init_params(cfg: ArchConfig, key: Array) -> dict:
    dtype = _dtype(cfg)
    vp = padded_vocab(cfg)
    ke, ko, kb = jax.random.split(key, 3)
    params: dict[str, Any] = {"final_norm": jnp.zeros((cfg.d_model,), dtype)}
    if cfg.embed_inputs:
        # 1/sqrt(d) keeps tied-head logits O(1) at init (RMSNorm rescales
        # the residual stream immediately, so forward magnitudes are safe)
        params["embed"] = ninit(ke, (vp, cfg.d_model), scale=cfg.d_model**-0.5,
                                dtype=dtype)
    if not cfg.tie_embeddings or not cfg.embed_inputs:
        params["out_head"] = ninit(ko, (cfg.d_model, vp), dtype=dtype)

    period, groups = cfg.layer_period, cfg.n_groups
    blocks: dict[str, Any] = {}
    for j in range(period):
        keys = jax.random.split(jax.random.fold_in(kb, j), groups)
        per_group = [_block_init(keys[g], cfg, j, dtype) for g in range(groups)]
        blocks[f"blk{j}"] = jax.tree.map(lambda *xs: jnp.stack(xs), *per_group)
    params["blocks"] = blocks
    return params


# =============================================================================
# sharding specs (leaf-name rules)
# =============================================================================

_SPEC_RULES: dict[str, tuple[Optional[str], ...]] = {
    # attention
    "wq": ("fsdp", "model"), "wk": ("fsdp", "model"), "wv": ("fsdp", "model"),
    "wo": ("model", "fsdp"),
    # mlp
    "w_gate": ("fsdp", "model"), "w_up": ("fsdp", "model"), "w_down": ("model", "fsdp"),
    # mamba
    "in_proj": ("fsdp", "model"), "out_proj": ("model", "fsdp"),
    "conv_w": (None, "model"),
    # io
    "embed": ("model", "fsdp"), "out_head": ("fsdp", "model"),
    "router": (None, None),
}


def _moe_spec(cfg: ArchConfig, name: str) -> tuple[Optional[str], ...]:
    mode = cfg.moe_mode(TP)
    if name in ("w_gate", "w_up"):
        return {"ep": ("model", "fsdp", None), "tp": (None, "fsdp", "model"),
                "replicate": (None, "fsdp", None)}[mode]
    return {"ep": ("model", None, "fsdp"), "tp": (None, "model", "fsdp"),
            "replicate": (None, None, "fsdp")}[mode]


def param_specs(cfg: ArchConfig, ctx: MeshCtx) -> Any:
    """PartitionSpec pytree mirroring init_params' structure."""
    params = jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))

    def spec_of(path, leaf) -> P:
        keys = [p.key for p in path if hasattr(p, "key")]
        name = keys[-1]
        in_moe = "moe" in keys and "shared" not in keys  # shared expert = dense MLP
        stacked = keys and keys[0] == "blocks"
        if in_moe and name in ("w_gate", "w_up", "w_down"):
            logical = _moe_spec(cfg, name)
        elif name in _SPEC_RULES:
            logical = _SPEC_RULES[name]
        else:
            logical = (None,) * (leaf.ndim - (1 if stacked else 0))
        if stacked:
            logical = (None,) + logical
        assert len(logical) == leaf.ndim, (keys, leaf.shape, logical)
        return logical_to_spec(*logical, ctx=ctx)

    return jax.tree_util.tree_map_with_path(spec_of, params)


# =============================================================================
# forward
# =============================================================================


def _attn_mixer(p: dict, cfg: ArchConfig, x: Array, positions: Array,
                mrope_pos: Optional[Array]) -> Array:
    b, s, _ = x.shape
    hp = cfg.padded_heads(TP)
    hd = cfg.head_dim
    q = lowp(x @ p["wq"]).reshape(b, s, hp, hd)
    k = lowp(x @ p["wk"]).reshape(b, s, -1, hd)
    v = lowp(x @ p["wv"]).reshape(b, s, -1, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    if cfg.pos == "rope":
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    elif cfg.pos == "mrope":
        q = apply_mrope(q, mrope_pos, cfg.rope_theta, cfg.mrope_sections)
        k = apply_mrope(k, mrope_pos, cfg.rope_theta, cfg.mrope_sections)
    if cfg.attention_impl == "bless_nystrom" and s > cfg.nystrom_landmarks:
        out = nystrom_attention(q, k, v, landmarks=cfg.nystrom_landmarks)
    else:
        out = attention(q, k, v, causal=cfg.causal, chunk=cfg.attn_chunk,
                        softcap=cfg.attn_logit_softcap)
    if hp != cfg.n_heads:  # mask padded q-heads: exact, shard-friendly
        mask = (jnp.arange(hp) < cfg.n_heads).astype(out.dtype)
        out = out * mask[None, None, :, None]
    return out.reshape(b, s, hp * hd) @ p["wo"]


def _block_apply(p: dict, cfg: ArchConfig, j: int, x: Array, positions: Array,
                 mrope_pos: Optional[Array]) -> Array:
    # optimization_barrier after each residual update pins the bf16 dtype at
    # the TP psum: without it XLA hoists the next norm's f32 upcast across
    # the all-reduce, doubling fwd collective bytes (EXPERIMENTS.md §Perf)
    h = rms_norm(x, p["ln_mix"], cfg.norm_eps)
    if cfg.mixer_kind(j) == "attn":
        x = x + _attn_mixer(p["attn"], cfg, h, positions, mrope_pos)
    else:
        x = x + mamba_block(p["mamba"], cfg, h)
    x = _opt_barrier(x)
    kind = cfg.mlp_kind(j)
    if kind == "none":
        return x
    h = rms_norm(x, p["ln_mlp"], cfg.norm_eps)
    if kind == "moe":
        x = x + moe_apply(p["moe"], h, top_k=cfg.top_k, n_experts=cfg.n_experts,
                          act=cfg.mlp_act, capacity_factor=cfg.capacity_factor,
                          ep=cfg.moe_ep(TP))
    else:
        x = x + mlp_apply(p["mlp"], h, cfg.mlp_act)
    return _opt_barrier(x)


def _embed_in(params: dict, cfg: ArchConfig, batch: dict) -> Array:
    if not cfg.embed_inputs:  # audio: precomputed frame embeddings (stub frontend)
        x = batch["frames"].astype(_dtype(cfg))
        return x + sinusoidal_pos(x.shape[1], cfg.d_model, x.dtype)[None]
    x = params["embed"][batch["tokens"]]
    if cfg.extra_image_tokens:  # vlm: patch embeds occupy a static prefix
        n = cfg.extra_image_tokens
        x = jnp.concatenate([batch["pixel_embeds"].astype(x.dtype), x[:, n:]], axis=1)
    return x


def forward(params: dict, cfg: ArchConfig, batch: dict) -> Array:
    """Full-sequence forward -> final hidden states (B, S, d)."""
    from ..sharding.rules import shard

    x = _embed_in(params, cfg, batch)
    x = shard(x, "batch", None, None)  # residual stream: batch-sharded
    b, s, _ = x.shape
    positions = batch.get("positions")
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    mrope_pos = batch.get("mrope_positions")

    period = cfg.layer_period

    def one_block(x, bparams, j):
        return _block_apply(bparams, cfg, j, x, positions, mrope_pos)

    if cfg.remat:
        # remat per *layer*, not per period-group: a group-level checkpoint
        # would make the backward materialize all `period` layers'
        # intermediates at once (5x live memory for Jamba's 8-layer groups
        # — EXPERIMENTS.md §Perf iteration 10)
        one_block = jax.checkpoint(one_block, static_argnums=(2,),
                                   policy=jax.checkpoint_policies.nothing_saveable)

    def group_body(x, gparams):
        for j in range(period):
            x = one_block(x, gparams[f"blk{j}"], j)
        return x

    def scan_fn(x, gparams):
        return group_body(x, gparams), None

    x, _ = jax.lax.scan(scan_fn, x, params["blocks"])
    return rms_norm(x, params["final_norm"], cfg.norm_eps)


def logits_fn(params: dict, cfg: ArchConfig, h: Array) -> Array:
    w = params["embed"].T if cfg.tie_embeddings else params["out_head"]
    return h @ w


def loss_fn(params: dict, cfg: ArchConfig, batch: dict, *, n_chunks: int = 8) -> Array:
    """Chunked softmax cross-entropy: logits materialize one *sequence*
    chunk at a time ((B, S/n, Vp) per step, batch- and vocab-sharded) —
    never the full (B, S, Vp). Chunking over S keeps the batch axis
    sharding intact through every reshape."""
    from ..sharding.rules import shard

    h = forward(params, cfg, batch)
    b, s, d = h.shape
    w = (params["embed"].T if cfg.tie_embeddings else params["out_head"])
    vp = w.shape[1]
    n_chunks = min(n_chunks, s)
    assert s % n_chunks == 0, (s, n_chunks)
    sc = s // n_chunks
    valid_v = jnp.arange(vp) < cfg.vocab_size

    def per_chunk(args):
        hc, lc = args  # (B, sc, d), (B, sc)
        logits = (hc @ w).astype(jnp.float32)
        logits = shard(logits, "batch", None, "model")
        logits = jnp.where(valid_v[None, None, :], logits, -1e30)  # padded vocab
        lse = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, lc[..., None], axis=2)[..., 0] - lse
        return -jnp.sum(ll)

    hc = jnp.moveaxis(h.reshape(b, n_chunks, sc, d), 1, 0)
    lc = jnp.moveaxis(batch["labels"].reshape(b, n_chunks, sc), 1, 0)
    losses = jax.lax.map(per_chunk, (hc, lc))
    return jnp.sum(losses) / (b * s)


# =============================================================================
# decode (KV / SSM caches)
# =============================================================================


def init_cache(cfg: ArchConfig, batch_size: int, max_len: int,
               dtype=None) -> dict:
    """Cache pytree: per period-position j, stacked over groups."""
    dtype = dtype or _dtype(cfg)
    g = cfg.n_groups
    kvp = (cfg.padded_heads(TP) if cfg.n_kv_heads == cfg.n_heads else cfg.n_kv_heads)
    cache: dict[str, Any] = {}
    for j in range(cfg.layer_period):
        if cfg.mixer_kind(j) == "attn":
            cache[f"blk{j}"] = {
                "k": jnp.zeros((g, batch_size, max_len, kvp, cfg.head_dim), dtype),
                "v": jnp.zeros((g, batch_size, max_len, kvp, cfg.head_dim), dtype),
            }
        else:
            cache[f"blk{j}"] = {
                "conv": jnp.zeros((g, batch_size, cfg.ssm_conv - 1,
                                   cfg.d_inner + 2 * cfg.ssm_state), dtype),
                "state": jnp.zeros((g, batch_size, cfg.ssm_heads, cfg.ssm_headdim,
                                    cfg.ssm_state), jnp.float32),
            }
    return cache


def cache_specs(cfg: ArchConfig, ctx: MeshCtx, *, seq_logical: str = "none") -> Any:
    """Sharding for the cache. seq_logical: 'none' (replicated seq),
    'seq_shard' (data) or 'seq_shard_wide' (data+model) for long-context."""
    cache = jax.eval_shape(lambda: init_cache(cfg, 1, 8))

    def spec_of(path, leaf) -> P:
        name = path[-1].key
        if name in ("k", "v"):
            return logical_to_spec(None, "batch", seq_logical, None, None, ctx=ctx)
        if name == "conv":
            return logical_to_spec(None, "batch", None, "model", ctx=ctx)
        if name == "state":
            return logical_to_spec(None, "batch", "model", None, None, ctx=ctx)
        return logical_to_spec(*([None] * leaf.ndim), ctx=ctx)

    return jax.tree_util.tree_map_with_path(spec_of, cache)


def _attn_decode(p: dict, cfg: ArchConfig, x: Array, cache: dict, pos: Array,
                 length: Optional[Array], mrope_pos: Optional[Array]) -> tuple[Array, dict]:
    b = x.shape[0]
    hp = cfg.padded_heads(TP)
    hd = cfg.head_dim
    q = (x @ p["wq"]).reshape(b, 1, hp, hd)
    k = (x @ p["wk"]).reshape(b, 1, -1, hd)
    v = (x @ p["wv"]).reshape(b, 1, -1, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    pos_b = jnp.broadcast_to(jnp.asarray(pos).reshape(-1, 1), (b, 1))
    if cfg.pos == "rope":
        q = apply_rope(q, pos_b, cfg.rope_theta)
        k = apply_rope(k, pos_b, cfg.rope_theta)
    elif cfg.pos == "mrope":
        q = apply_mrope(q, mrope_pos, cfg.rope_theta, cfg.mrope_sections)
        k = apply_mrope(k, mrope_pos, cfg.rope_theta, cfg.mrope_sections)
    s_max = cache["k"].shape[1]
    slot = (pos_b[:, 0] % s_max).astype(jnp.int32)  # per-slot write position
    bidx = jnp.arange(b)
    kc = cache["k"].at[bidx, slot].set(k[:, 0].astype(cache["k"].dtype))
    vc = cache["v"].at[bidx, slot].set(v[:, 0].astype(cache["v"].dtype))
    out = decode_attention(q, kc, vc, softcap=cfg.attn_logit_softcap, length=length)
    if hp != cfg.n_heads:
        mask = (jnp.arange(hp) < cfg.n_heads).astype(out.dtype)
        out = out * mask[None, None, :, None]
    out = out.reshape(b, 1, hp * hd) @ p["wo"]
    return out, {"k": kc, "v": vc}


def decode_step(params: dict, cfg: ArchConfig, cache: dict, token: Array, pos: Array,
                *, length: Optional[Array] = None,
                mrope_pos: Optional[Array] = None) -> tuple[Array, dict]:
    """One decode step. token (B,) int32; pos () int32. Returns
    (logits (B, Vp), new cache)."""
    assert cfg.has_decode, f"{cfg.name} is encoder-only"
    x = params["embed"][token][:, None, :]  # (B, 1, d)

    period = cfg.layer_period
    new_cache: dict[str, Any] = {}

    def group_body(x, slices):
        gparams, gcache = slices
        outc = {}
        for j in range(period):
            p = gparams[f"blk{j}"]
            h = rms_norm(x, p["ln_mix"], cfg.norm_eps)
            if cfg.mixer_kind(j) == "attn":
                out, c = _attn_decode(p["attn"], cfg, h[:, 0], gcache[f"blk{j}"], pos,
                                      length, mrope_pos)
            else:
                out, c = mamba_decode(p["mamba"], cfg, h, gcache[f"blk{j}"])
            x = x + out
            outc[f"blk{j}"] = c
            kind = cfg.mlp_kind(j)
            if kind != "none":
                h = rms_norm(x, p["ln_mlp"], cfg.norm_eps)
                if kind == "moe":
                    x = x + moe_apply(p["moe"], h, top_k=cfg.top_k,
                                      n_experts=cfg.n_experts, act=cfg.mlp_act,
                                      capacity_factor=cfg.capacity_factor,
                                      ep=cfg.moe_ep(TP))
                else:
                    x = x + mlp_apply(p["mlp"], h, cfg.mlp_act)
        return x, outc

    x, new_cache = jax.lax.scan(group_body, x, (params["blocks"], cache))
    h = rms_norm(x[:, 0], params["final_norm"], cfg.norm_eps)
    return logits_fn(params, cfg, h), new_cache
