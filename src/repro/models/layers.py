"""Shared neural layers: norms, rotary embeddings, MLPs, initializers.

Pure-jnp, pjit-shardable (sharding enters only via repro.sharding.shard
annotations in model.py). Parameters are plain nested dicts.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

Array = jax.Array


# --- init ------------------------------------------------------------------


def ninit(key: Array, shape, scale: float | None = None, dtype=jnp.bfloat16) -> Array:
    """Truncated-normal fan-in init."""
    fan_in = shape[0] if len(shape) > 1 else shape[-1]
    scale = (1.0 / math.sqrt(fan_in)) if scale is None else scale
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * scale).astype(dtype)


# --- norms -----------------------------------------------------------------


from functools import partial as _partial


@_partial(jax.custom_vjp, nondiff_argnums=(2,))
def rms_norm(x: Array, gamma: Array, eps: float = 1e-6) -> Array:
    """RMSNorm with an explicit low-precision *gradient boundary*.

    Internals run in f32, but dx is cast back to x.dtype before leaving the
    op. Without this, XLA hoists the f32 upcast across the TP all-reduces
    that sit just upstream (the row-parallel matmul psums), doubling every
    per-layer collective on the backward pass — measured at ~2x the total
    train collective volume (EXPERIMENTS.md §Perf iteration 4).
    """
    return _rms_fwd(x, gamma, eps)[0]


def _rms_fwd(x, gamma, eps):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps)
    y = (xf * inv) * (1.0 + gamma.astype(jnp.float32))
    return y.astype(x.dtype), (x, gamma, inv)


def _rms_bwd(eps, res, dy):
    x, gamma, inv = res
    xf = x.astype(jnp.float32)
    dyf = dy.astype(jnp.float32)
    g1 = 1.0 + gamma.astype(jnp.float32)
    xhat = xf * inv
    dxhat = dyf * g1
    # d/dx of x * rsqrt(mean(x^2)+eps)
    dx = inv * (dxhat - xhat * jnp.mean(dxhat * xhat, axis=-1, keepdims=True))
    dgamma = jnp.sum(dyf * xhat, axis=tuple(range(x.ndim - 1)))
    return dx.astype(x.dtype), dgamma.astype(gamma.dtype)


rms_norm.defvjp(_rms_fwd, _rms_bwd)


@jax.custom_vjp
def lowp(x: Array) -> Array:
    """Identity with a low-precision *gradient* boundary.

    Placed on TP-matmul outputs (q/k/v projections): upstream attention math
    runs in f32 (softmax, rope), so without this the cotangent arriving at
    the transposed projection matmul — and therefore the per-layer TP
    all-reduce of dx — is f32. The boundary casts it back to the forward
    dtype, halving backward collective bytes (EXPERIMENTS.md §Perf)."""
    return x


def _lowp_fwd(x):
    return x, jnp.zeros((0,), x.dtype)


def _lowp_bwd(res, dy):
    return (dy.astype(res.dtype),)


lowp.defvjp(_lowp_fwd, _lowp_bwd)


# --- rotary ----------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> Array:
    return 1.0 / theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)


def apply_rope(x: Array, positions: Array, theta: float) -> Array:
    """x (..., S, H, D), positions (..., S) -> rotated x (half-split layout)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)  # (D/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, D/2)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]  # (..., S, 1, D/2)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: Array, positions_3d: Array, theta: float,
                sections: tuple[int, int, int]) -> Array:
    """Qwen2-VL multimodal RoPE. positions_3d (B, 3, S); the D/2 frequency
    slots are partitioned into (t, h, w) sections, each rotated by its own
    position stream. Equal streams reduce exactly to standard RoPE."""
    d = x.shape[-1]
    half = d // 2
    tot = sum(sections)
    sec = [s * half // tot for s in sections]  # static rescale to head_dim/2
    sec[-1] += half - sum(sec)
    bounds = jnp.asarray([sec[0], sec[0] + sec[1], half])
    slot = jnp.arange(half)
    which = (slot[None, :] >= bounds[:, None]).sum(0)  # (half,) in {0,1,2}
    freqs = rope_freqs(d, theta)  # (half,)
    # pos per slot: (B, S, half)
    pos = jnp.take_along_axis(
        positions_3d.transpose(0, 2, 1).astype(jnp.float32),  # (B, S, 3)
        jnp.broadcast_to(which, positions_3d.shape[0:1] + (positions_3d.shape[2], half)),
        axis=-1,
    )
    ang = pos * freqs  # (B, S, half)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_pos(seq: int, d_model: int, dtype=jnp.bfloat16) -> Array:
    pos = jnp.arange(seq, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, d_model, 2, dtype=jnp.float32)[None, :]
    ang = pos / (10_000.0 ** (dim / d_model))
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1).astype(dtype)


# --- mlp -------------------------------------------------------------------


def act_fn(name: str, x: Array) -> Array:
    if name in ("swiglu", "silu"):
        return jax.nn.silu(x)
    if name in ("geglu", "gelu"):
        return jax.nn.gelu(x, approximate=True)
    raise ValueError(name)


def mlp_apply(p: dict, x: Array, act: str) -> Array:
    """Gated (SwiGLU/GeGLU) or plain (GELU) MLP. x (..., d); the hidden ff
    dim rides the model axis (Megatron column->row pair)."""
    from ..sharding.rules import shard

    if "w_gate" in p:
        h = act_fn(act, x @ p["w_gate"]) * (x @ p["w_up"])
    else:
        h = act_fn(act, x @ p["w_up"])
    h = shard(h, *(("batch",) + (None,) * (h.ndim - 2) + ("model",)))
    return h @ p["w_down"]


def mlp_init(key: Array, d: int, ff: int, act: str, dtype=jnp.bfloat16) -> dict:
    ks = jax.random.split(key, 3)
    p = {"w_up": ninit(ks[0], (d, ff), dtype=dtype),
         "w_down": ninit(ks[1], (ff, d), dtype=dtype)}
    if act in ("swiglu", "geglu"):
        p["w_gate"] = ninit(ks[2], (d, ff), dtype=dtype)
    return p
