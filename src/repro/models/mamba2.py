"""Mamba-2 / SSD (state-space duality, arXiv:2405.21060) block.

TPU adaptation (DESIGN.md §2): the chunked SSD algorithm is expressed as
four einsums per chunk (intra-chunk "attention-like" term, chunk-state
build, inter-chunk state scan, state-to-output) so all heavy work lands on
the MXU; the only sequential op is the O(S/Q) inter-chunk scan. This is the
matmul-form of SSD rather than a port of the CUDA selective-scan.

Shapes follow the paper: x (B, S, H, P), dt (B, S, H), A (H,) negative,
B/C (B, S, G, N) with G groups (G=1 here), state (B, H, P, N).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import ninit, rms_norm

Array = jax.Array


def _segsum(x: Array) -> Array:
    """Stable segment-sum: out[..., i, j] = sum_{k=j+1..i} x[..., k] (j < i)."""
    t = x.shape[-1]
    c = jnp.cumsum(x, axis=-1)
    diff = c[..., :, None] - c[..., None, :]
    mask = jnp.tril(jnp.ones((t, t), bool), k=0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x: Array, dt: Array, a: Array, b: Array, c: Array, *, chunk: int = 256,
                init_state: Array | None = None) -> tuple[Array, Array]:
    """Chunked SSD scan. Returns (y (B,S,H,P), final_state (B,H,P,N))."""
    bsz, s, h, p = x.shape
    n = b.shape[-1]
    q = min(chunk, s)
    assert s % q == 0, (s, q)
    nc = s // q
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    da = dtf * a.astype(jnp.float32)  # (B, S, H) log-decay increments (<0)

    xc = xf.reshape(bsz, nc, q, h, p)
    dac = da.reshape(bsz, nc, q, h)
    dtc = dtf.reshape(bsz, nc, q, h)
    bc = b.astype(jnp.float32).reshape(bsz, nc, q, 1, n)  # G=1 broadcast over H
    cc = c.astype(jnp.float32).reshape(bsz, nc, q, 1, n)

    # 1) intra-chunk (diagonal blocks): y_diag = (C B^T  *  decay) (dt x)
    l = jnp.exp(_segsum(dac.transpose(0, 1, 3, 2)))  # (B, nc, H, Q, Q)
    cb = jnp.einsum("bzqgn,bzkgn->bzqk", cc, bc)  # (B, nc, Q, Q)
    y_diag = jnp.einsum("bzqk,bzhqk,bzkh,bzkhp->bzqhp", cb, l, dtc, xc)

    # 2) per-chunk end-state: decay-to-end weighted sum of B (dt x)
    dec_end = jnp.exp(jnp.cumsum(dac, axis=2)[:, :, -1:, :] - jnp.cumsum(dac, axis=2))
    states = jnp.einsum("bzkgn,bzkh,bzkh,bzkhp->bzhpn", bc, dec_end, dtc, xc)

    # 3) inter-chunk recurrence over nc chunk states
    chunk_decay = jnp.exp(jnp.sum(dac, axis=2))  # (B, nc, H)
    s0 = jnp.zeros((bsz, h, p, n), jnp.float32) if init_state is None else init_state.astype(jnp.float32)

    def scan_fn(carry, inp):
        st, dec = inp  # (B,H,P,N), (B,H)
        new = st + dec[..., None, None] * carry
        return new, carry  # emit state *entering* the chunk

    final_state, prev_states = jax.lax.scan(
        scan_fn, s0, (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)  # (B, nc, H, P, N)

    # 4) inter-chunk output: C_t  decay-from-start  state_in
    dec_in = jnp.exp(jnp.cumsum(dac, axis=2))  # (B, nc, Q, H)
    y_off = jnp.einsum("bzqgn,bzqh,bzhpn->bzqhp", cc, dec_in, prev_states)

    y = (y_diag + y_off).reshape(bsz, s, h, p)
    return y.astype(x.dtype), final_state


def ssd_decode_step(state: Array, x_t: Array, dt_t: Array, a: Array, b_t: Array,
                    c_t: Array) -> tuple[Array, Array]:
    """One recurrent step. state (B,H,P,N); x_t (B,H,P); dt_t (B,H);
    b_t/c_t (B,N). Returns (y_t (B,H,P), new_state)."""
    sf = state.astype(jnp.float32)
    dtf = dt_t.astype(jnp.float32)
    decay = jnp.exp(dtf * a.astype(jnp.float32))  # (B, H)
    upd = jnp.einsum("bh,bhp,bn->bhpn", dtf, x_t.astype(jnp.float32), b_t.astype(jnp.float32))
    new = decay[..., None, None] * sf + upd
    y = jnp.einsum("bhpn,bn->bhp", new, c_t.astype(jnp.float32))
    return y.astype(x_t.dtype), new


# ---------------------------------------------------------------------------
# full block: in_proj -> conv -> SSD -> gated norm -> out_proj
# ---------------------------------------------------------------------------


def mamba_init(key: Array, cfg, dtype=jnp.bfloat16) -> dict:
    d, di, ns, nh = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    conv_dim = di + 2 * ns
    ks = jax.random.split(key, 5)
    return {
        "in_proj": ninit(ks[0], (d, 2 * di + 2 * ns + nh), dtype=dtype),
        "conv_w": ninit(ks[1], (cfg.ssm_conv, conv_dim), scale=0.5, dtype=dtype),
        "a_log": jnp.zeros((nh,), jnp.float32),  # A = -exp(a_log) = -1
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "d_skip": jnp.ones((nh,), jnp.float32),
        "norm": jnp.zeros((di,), dtype=dtype),
        "out_proj": ninit(ks[2], (di, d), dtype=dtype),
    }


def _split_proj(cfg, zxbcdt: Array):
    di, ns, nh = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    z, xbc, dt = jnp.split(zxbcdt, [di, 2 * di + 2 * ns], axis=-1)
    return z, xbc, dt  # xbc = [x (di), B (ns), C (ns)], dt (nh)


def mamba_block(p: dict, cfg, u: Array, *, chunk: int = 256) -> Array:
    """Full-sequence SSD mixer. u (B, S, d_model) -> (B, S, d_model)."""
    from ..sharding.rules import shard

    bsz, s, _ = u.shape
    di, ns, nh, hp = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_headdim
    z, xbc, dt = _split_proj(cfg, u @ p["in_proj"])
    # causal depthwise conv over (x, B, C)
    k = cfg.ssm_conv
    xbc_pad = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
    conv = sum(xbc_pad[:, i: i + s] * p["conv_w"][i][None, None, :] for i in range(k))
    conv = jax.nn.silu(conv)
    x, b, c = jnp.split(conv, [di, di + ns], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B, S, nh)
    a = -jnp.exp(p["a_log"])  # (nh,)
    # SSD heads ride the model axis: every per-head intermediate inside the
    # chunk scan (the (B,nc,H,Q,Q) decay tensor above all) is TP-sharded.
    x = shard(x.reshape(bsz, s, nh, hp), "batch", None, "model", None)
    dt = shard(dt, "batch", None, "model")
    y, _ = ssd_chunked(x, dt, a, b[:, :, None, :].reshape(bsz, s, 1, ns),
                       c[:, :, None, :].reshape(bsz, s, 1, ns), chunk=min(chunk, s))
    y = y + x * p["d_skip"][None, None, :, None].astype(y.dtype)
    y = shard(y, "batch", None, "model", None).reshape(bsz, s, di)
    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    return y @ p["out_proj"]


def mamba_decode(p: dict, cfg, u_t: Array, cache: dict) -> tuple[Array, dict]:
    """One-token step. u_t (B, 1, d); cache = {conv (B, k-1, conv_dim),
    state (B, H, P, N)}."""
    bsz = u_t.shape[0]
    di, ns, nh, hp = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_headdim
    z, xbc, dt = _split_proj(cfg, u_t[:, 0] @ p["in_proj"])  # (B, *)
    k = cfg.ssm_conv
    window = jnp.concatenate([cache["conv"], xbc[:, None, :]], axis=1)  # (B, k, conv)
    conv = jnp.einsum("bkc,kc->bc", window.astype(jnp.float32),
                      p["conv_w"].astype(jnp.float32)).astype(u_t.dtype)
    conv = jax.nn.silu(conv)
    x, b, c = jnp.split(conv, [di, di + ns], axis=-1)
    dtv = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B, nh)
    a = -jnp.exp(p["a_log"])
    y, new_state = ssd_decode_step(cache["state"], x.reshape(bsz, nh, hp), dtv, a, b, c)
    y = y + x.reshape(bsz, nh, hp) * p["d_skip"][None, :, None].astype(y.dtype)
    y = y.reshape(bsz, di)
    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out = (y @ p["out_proj"])[:, None, :]
    return out, {"conv": window[:, 1:], "state": new_state.astype(cache["state"].dtype)}
