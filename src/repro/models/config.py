"""Architecture configuration.

One frozen dataclass describes every assigned arch (dense / MoE / SSM /
hybrid / VLM-backbone / audio-encoder). ``block_kind(i)`` resolves the
per-layer mixer/mlp pattern (Jamba's 1:7 attn:mamba interleave with MoE on
odd layers, etc.); ``layer_period`` is the pattern period — the layer stack
scans over ``n_layers // layer_period`` stacked parameter groups.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Literal

Mixer = Literal["attn", "mamba"]
Mlp = Literal["dense", "moe", "none"]


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    # attention
    causal: bool = True
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    pos: str = "rope"  # rope | mrope | sinusoidal
    mrope_sections: tuple[int, int, int] = (16, 24, 24)  # of head_dim//2
    attention_impl: str = "full"  # full | bless_nystrom
    nystrom_landmarks: int = 1024  # for bless_nystrom
    attn_logit_softcap: float = 0.0

    # mlp
    mlp_act: str = "swiglu"  # swiglu | geglu | gelu
    # moe
    n_experts: int = 0
    top_k: int = 0
    moe_period: int = 1  # every `moe_period`-th layer is MoE (when n_experts>0)
    shared_expert_ff: int = 0  # llama4-style always-on shared expert
    capacity_factor: float = 1.25
    moe_sharding: str = "auto"  # auto | ep (experts->model) | tp (ff->model)
    #                             | replicate (small experts: no model shard)
    # ssm (mamba2 / SSD)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_headdim: int = 64
    attn_period: int = 0  # hybrid: 1 attention layer per `attn_period` (jamba=8)
    attn_offset: int = 4  # position of the attn layer inside a period group

    # embeddings / io
    tie_embeddings: bool = False
    embed_inputs: bool = True  # False => inputs are precomputed embeddings (audio)
    extra_image_tokens: int = 0  # vlm: prefix patch-embeds scattered into seq
    has_decode: bool = True  # encoder-only archs: False

    # numerics / distribution
    dtype: str = "bfloat16"
    norm_eps: float = 1e-6
    remat: bool = True
    attn_chunk: int = 512  # q-chunk for memory-bounded full attention

    # --- derived -----------------------------------------------------------
    @property
    def d_inner(self) -> int:  # mamba2
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_headdim

    @property
    def layer_period(self) -> int:
        p = 1
        if self.attn_period:
            p = self.attn_period
        if self.n_experts and self.moe_period > 1:
            p = _lcm(p, self.moe_period)
        return p

    @property
    def n_groups(self) -> int:
        assert self.n_layers % self.layer_period == 0, (self.n_layers, self.layer_period)
        return self.n_layers // self.layer_period

    def mixer_kind(self, i: int) -> Mixer:
        if self.family == "ssm":
            return "mamba"
        if self.attn_period:
            return "attn" if i % self.attn_period == self.attn_offset else "mamba"
        return "attn"

    def mlp_kind(self, i: int) -> Mlp:
        if self.d_ff == 0 and not self.n_experts:
            return "none"
        if self.n_experts and i % self.moe_period == self.moe_period - 1:
            return "moe"
        return "dense"

    def moe_mode(self, tp: int = 16) -> str:
        """'ep' (experts->model), 'tp' (per-expert ff->model) or
        'replicate' (tiny experts: keep MoE weights model-replicated; all
        dispatch/compute batch-parallel, zero MoE collectives)."""
        if self.moe_sharding != "auto":
            return self.moe_sharding
        if self.n_experts % tp == 0:
            return "ep"
        return "tp" if self.d_ff >= 64 * tp else "replicate"

    def moe_ep(self, tp: int = 16) -> bool:
        return self.moe_mode(tp) == "ep"

    def padded_heads(self, tp: int = 16) -> int:
        """q-heads padded to a multiple of the model axis (zero o_proj rows —
        exact; the overhead is reported in the roofline waste ratio)."""
        return math.ceil(self.n_heads / tp) * tp

    def param_count(self) -> int:
        """Analytic parameter count (embeddings + blocks), for 6ND roofline."""
        total = self.vocab_size * self.d_model * (1 if self.tie_embeddings else 2)
        for i in range(self.n_layers):
            d = self.d_model
            if self.mixer_kind(i) == "attn":
                qkv = d * self.n_heads * self.head_dim + 2 * d * self.n_kv_heads * self.head_dim
                total += qkv + self.n_heads * self.head_dim * d
                if self.qk_norm:
                    total += 2 * self.head_dim
            else:
                di, ns, nh = self.d_inner, self.ssm_state, self.ssm_heads
                total += d * (2 * di + 2 * ns + nh)  # in_proj (z,x,B,C,dt)
                total += (di + 2 * ns) * self.ssm_conv  # conv
                total += 3 * nh + di  # A_log, dt_bias, D, norm... (approx)
                total += di * d  # out_proj
            kind = self.mlp_kind(i)
            mult = 3 if self.mlp_act in ("swiglu", "geglu") else 2
            if kind == "dense":
                total += mult * d * self.d_ff
            elif kind == "moe":
                total += d * self.n_experts  # router
                total += self.n_experts * mult * d * self.d_ff
                if self.shared_expert_ff:
                    total += mult * d * self.shared_expert_ff
            total += 2 * d  # norms
        return total

    def active_param_count(self) -> int:
        """Per-token active params (MoE: top_k experts + shared)."""
        if not self.n_experts:
            return self.param_count()
        total = self.param_count()
        mult = 3 if self.mlp_act in ("swiglu", "geglu") else 2
        n_moe_layers = sum(1 for i in range(self.n_layers) if self.mlp_kind(i) == "moe")
        inactive = n_moe_layers * (self.n_experts - self.top_k) * mult * self.d_model * self.d_ff
        return total - inactive


def _lcm(a: int, b: int) -> int:
    return a * b // math.gcd(a, b)
