"""Attention variants: chunked-full (train/prefill), decode w/ KV cache,
and BLESS-Nystrom sub-quadratic attention (the paper's technique in the LM).

All paths are pure jnp + lax so they lower for any mesh; the Pallas flash
kernel (repro.kernels.flash_attention) is the TPU drop-in for the chunked
path (use_pallas flag in model.py).

BLESS-Nystrom (DESIGN.md §3): softmax attention against M landmark keys
selected by *ridge leverage scores* of the key Gram matrix (Gaussian kernel
at bandwidth sqrt(head_dim), one rung of the BLESS ladder evaluated
in-graph with a uniform pilot set — top-M by score replaces multinomial
sampling to keep shapes static). Used for (a) sub-quadratic encoder/prefill
attention and (b) leverage-score KV-cache compression at decode, which is
what makes long_500k lowerable for a *dense* arch.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

Array = jax.Array
_NEG = -1e30


def _repeat_kv(x: Array, group: int) -> Array:
    """(B, S, Hkv, D) -> (B, S, Hq, D) by group replication."""
    if group == 1:
        return x
    b, s, h, d = x.shape
    return jnp.broadcast_to(x[:, :, :, None, :], (b, s, h, group, d)).reshape(b, s, h * group, d)


def _merge_chunks(out: Array, b: int, hq: int, nc: int, chunk: int, d: int, s: int) -> Array:
    """(nc, B, Hq, c, D) -> (B, S, Hq, D)."""
    return out.transpose(1, 0, 3, 2, 4).reshape(b, nc * chunk, hq, d)[:, :s]


def attention(q: Array, k: Array, v: Array, *, causal: bool, chunk: int = 512,
              softcap: float = 0.0) -> Array:
    """Public exact attention: chunked when S > chunk, single-shot otherwise.

    q heads ride the model axis (GQA kv stays replicated and is broadcast
    locally — each chip's q-head slice reads its own kv group)."""
    from ..sharding.rules import shard

    b, s, hq, d = q.shape
    hkv = k.shape[2]
    scale = 1.0 / math.sqrt(d)
    q = shard(q, "batch", None, "model", None)
    kf = _repeat_kv(k, hq // hkv).transpose(0, 2, 1, 3)
    vf = _repeat_kv(v, hq // hkv).transpose(0, 2, 1, 3)
    kf = shard(kf, "batch", "model", None, None)
    vf = shard(vf, "batch", "model", None, None)
    kpos = jnp.arange(s)

    def run_chunk(qi: Array, q0: Array) -> Array:
        # qi (B, Hq, c, D); q0 scalar chunk start
        scores = jnp.einsum("bhqd,bhkd->bhqk", qi.astype(jnp.float32),
                            kf.astype(jnp.float32)) * scale
        if softcap > 0.0:
            scores = softcap * jnp.tanh(scores / softcap)
        if causal:
            qpos = q0 + jnp.arange(qi.shape[2])
            scores = jnp.where(qpos[:, None] >= kpos[None, :], scores, _NEG)
        p = jax.nn.softmax(scores, axis=-1)
        return jnp.einsum("bhqk,bhkd->bhqd", p, vf.astype(jnp.float32)).astype(q.dtype)

    qt = q.transpose(0, 2, 1, 3)  # (B, Hq, S, D)
    if s <= chunk:
        return run_chunk(qt, jnp.asarray(0)).transpose(0, 2, 1, 3)
    pad = (-s) % chunk
    qp = jnp.pad(qt, ((0, 0), (0, 0), (0, pad), (0, 0)))
    nc = qp.shape[2] // chunk
    qc = qp.reshape(b, hq, nc, chunk, d).transpose(2, 0, 1, 3, 4)
    out = jax.lax.map(lambda args: run_chunk(args[1], args[0] * chunk),
                      (jnp.arange(nc), qc))  # (nc, B, Hq, c, D)
    return _merge_chunks(out, b, hq, nc, chunk, d, s)


def decode_attention(q: Array, k_cache: Array, v_cache: Array, *,
                     softcap: float = 0.0, length: Array | None = None) -> Array:
    """Single-token decode. q (B, 1, Hq, D); caches (B, S, Hkv, D).

    The cache S dim may be sharded (SP decode): softmax max/sum reductions
    over S are inserted as cross-shard collectives by the SPMD partitioner.
    """
    b, s, hkv, d = k_cache.shape
    hq = q.shape[2]
    group = hq // hkv
    scale = 1.0 / math.sqrt(d)
    qg = q[:, 0].reshape(b, hkv, group, d)  # (B, Hkv, G, D)
    scores = jnp.einsum("bkgd,bskd->bkgs", qg.astype(jnp.float32),
                        k_cache.astype(jnp.float32)) * scale
    if softcap > 0.0:
        scores = softcap * jnp.tanh(scores / softcap)
    if length is not None:  # scalar or per-slot (B,) lengths
        lens = jnp.asarray(length).reshape(-1, 1, 1, 1)
        scores = jnp.where(jnp.arange(s)[None, None, None, :] < lens, scores, _NEG)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p, v_cache.astype(jnp.float32))
    return out.reshape(b, 1, hq, d).astype(q.dtype)


# ---------------------------------------------------------------------------
# BLESS-Nystrom: leverage-score landmarks (the paper's technique, in-graph)
# ---------------------------------------------------------------------------


def rls_scores_one_rung(keys: Array, m_pilot: int, lam: float) -> Array:
    """One BLESS rung: Eq. 3 scores of every key against a uniform pilot set.

    keys (S, D). Gaussian kernel at bandwidth^2 = sqrt(D) (softmax-kernel
    proxy, see module docstring). Pilot = strided subset (deterministic —
    the in-graph analogue of the uniform U_h; DESIGN.md §3).
    """
    s, d = keys.shape
    kf = keys.astype(jnp.float32)
    inv = 1.0 / (2.0 * math.sqrt(d))
    stride = max(1, s // m_pilot)
    pilot = kf[::stride][:m_pilot]
    mp = pilot.shape[0]

    def gram(a, b):
        d2 = (jnp.sum(a * a, -1)[:, None] + jnp.sum(b * b, -1)[None, :] - 2 * a @ b.T)
        return jnp.exp(-jnp.maximum(d2, 0.0) * inv)

    kjj = gram(pilot, pilot) + (lam * s * (mp / s) + 1e-5) * jnp.eye(mp)
    g = gram(kf, pilot)  # (S, mp)
    chol = jnp.linalg.cholesky(kjj)
    vsol = jax.scipy.linalg.solve_triangular(chol, g.T, lower=True)
    quad = jnp.sum(vsol * vsol, axis=0)
    return jnp.clip((1.0 - quad) / (lam * s), 1e-12, 1.0)  # K_ii = 1 (gaussian)


def bless_topm_landmarks(keys: Array, m: int, *, m_pilot: int = 128,
                         lam: float = 1e-3) -> Array:
    """Indices (m,) of the top-m leverage-score keys. keys (S, D)."""
    scores = rls_scores_one_rung(keys, m_pilot, lam)
    return jax.lax.top_k(scores, m)[1]


def nystrom_attention(q: Array, k: Array, v: Array, *, landmarks: int,
                      lam: float = 1e-3) -> Array:
    """Sub-quadratic bidirectional attention via RLS landmarks.

    q (B, S, Hq, D), k/v (B, S, Hkv, D); cost O(S * M) with M = landmarks.
      out = softmax(Q K_L^T) @ pinv(softmax(Q_L K_L^T)) @ softmax(Q_L K^T) V
    Landmarks are per (batch, kv-head) leverage-score top-M keys.
    """
    b, s, hq, d = q.shape
    hkv = k.shape[2]
    group = hq // hkv
    scale = 1.0 / math.sqrt(d)
    m = min(landmarks, s)

    def per_bh(qh, kh, vh):
        # qh (group, S, D) for this kv head; kh/vh (S, D)
        idx = bless_topm_landmarks(kh, m, lam=lam)
        kl, ql = kh[idx], qh[:, idx]  # (m, D), (group, m, D)
        f1 = jax.nn.softmax(jnp.einsum("gsd,md->gsm", qh, kl) * scale, axis=-1)
        a = jax.nn.softmax(jnp.einsum("gmd,nd->gmn", ql, kl) * scale, axis=-1)
        f2 = jax.nn.softmax(jnp.einsum("gmd,sd->gms", ql, kh) * scale, axis=-1)
        a_pinv = _iterative_pinv(a)
        return jnp.einsum("gsm,gmn->gsn", f1, a_pinv) @ (f2 @ vh.astype(jnp.float32))

    qf = q.astype(jnp.float32).reshape(b, s, hkv, group, d).transpose(0, 2, 3, 1, 4)
    kf = k.astype(jnp.float32).transpose(0, 2, 1, 3)  # (B, Hkv, S, D)
    vf = v.astype(jnp.float32).transpose(0, 2, 1, 3)
    out = jax.vmap(jax.vmap(per_bh))(qf, kf, vf)  # (B, Hkv, group, S, D)
    return out.transpose(0, 3, 1, 2, 4).reshape(b, s, hq, d).astype(q.dtype)


def _iterative_pinv(a: Array, iters: int = 6) -> Array:
    """Newton-Schulz pseudo-inverse (Nystromformer Eq. 16) — jit-friendly."""
    eye = jnp.eye(a.shape[-1], dtype=a.dtype)
    z = a.swapaxes(-1, -2) / (jnp.max(jnp.sum(jnp.abs(a), -1), -1, keepdims=True)[..., None]
                              * jnp.max(jnp.sum(jnp.abs(a), -2), -1, keepdims=True)[..., None])
    for _ in range(iters):
        az = a @ z
        z = 0.25 * z @ (13.0 * eye - az @ (15.0 * eye - az @ (7.0 * eye - az)))
    return z


def bless_compress_cache(k_cache: Array, v_cache: Array, m: int, *,
                         m_pilot: int = 256, lam: float = 1e-4) -> tuple[Array, Array]:
    """Leverage-score KV-cache compression: keep the top-m RLS keys per
    (batch, kv head). caches (B, S, Hkv, D) -> (B, m, Hkv, D)."""

    def per_bh(kh, vh):
        idx = bless_topm_landmarks(kh, m, m_pilot=m_pilot, lam=lam)
        return kh[idx], vh[idx]

    kt = k_cache.transpose(0, 2, 1, 3)
    vt = v_cache.transpose(0, 2, 1, 3)
    kc, vc = jax.vmap(jax.vmap(per_bh))(kt, vt)
    return kc.transpose(0, 2, 1, 3), vc.transpose(0, 2, 1, 3)
