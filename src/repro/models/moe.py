"""Mixture-of-Experts with group-local sort-based dispatch.

Tokens are processed in *groups* (one group per sequence), so routing —
softmax, top-k, argsort, rank-within-expert, capacity drop — is entirely
local to the batch-sharded axis; the only cross-device movement is the
all-to-all the SPMD partitioner inserts around the expert einsum when
experts are sharded over the model axis (EP).

Dispatch is sort-based (MegaBlocks-style) rather than GShard one-hot
einsums: the (S*k, E) one-hot only feeds a cumsum for intra-expert ranks,
never a (T, E, C) dispatch tensor, so memory is O(S*k*E) ints per group
instead of O(T*E*C) floats globally.

Expert sharding (DESIGN.md §5): experts->model when E % 16 == 0 (llama4 16e,
jamba 16e: one expert per chip), else per-expert ff->model (granite 40e,
d_ff 512 -> 32 cols/chip). Chosen per-config via ``moe_sharding``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import act_fn, ninit

Array = jax.Array


def moe_init(key: Array, d: int, ff: int, n_experts: int, act: str,
             shared_ff: int = 0, dtype=jnp.bfloat16) -> dict:
    ks = jax.random.split(key, 5)
    p = {
        "router": ninit(ks[0], (d, n_experts), dtype=jnp.float32),
        "w_up": ninit(ks[1], (n_experts, d, ff), dtype=dtype),
        "w_down": ninit(ks[2], (n_experts, ff, d), dtype=dtype),
    }
    if act in ("swiglu", "geglu"):
        p["w_gate"] = ninit(ks[3], (n_experts, d, ff), dtype=dtype)
    if shared_ff:
        from .layers import mlp_init

        p["shared"] = mlp_init(ks[4], d, shared_ff, act, dtype)
    return p


def _route_group(x: Array, router: Array, top_k: int, capacity: int, n_experts: int):
    """Per-group routing. x (S, d) -> dispatch metadata.

    Returns (slot, gate, keep):
      slot (S*k,) int32 in [0, E*C]  — flat expert-buffer slot (E*C = dropped)
      gate (S*k,) f32               — renormalized top-k router prob
      src  (S*k,) int32             — source token index
    """
    s = x.shape[0]
    logits = (x.astype(jnp.float32) @ router).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)  # (S, E)
    top_p, top_e = jax.lax.top_k(probs, top_k)  # (S, k)
    top_p = top_p / jnp.maximum(jnp.sum(top_p, -1, keepdims=True), 1e-9)
    flat_e = top_e.reshape(-1)  # (S*k,)
    flat_p = top_p.reshape(-1)
    src = jnp.repeat(jnp.arange(s, dtype=jnp.int32), top_k)
    # stable sort by expert => rank within expert via cumsum of one-hot
    order = jnp.argsort(flat_e, stable=True)
    e_sorted = flat_e[order]
    onehot = jax.nn.one_hot(e_sorted, n_experts, dtype=jnp.int32)
    rank = jnp.take_along_axis(jnp.cumsum(onehot, axis=0) - 1, e_sorted[:, None], axis=1)[:, 0]
    keep = rank < capacity
    slot_sorted = jnp.where(keep, e_sorted * capacity + rank, n_experts * capacity)
    # scatter back to original (S*k,) order
    slot = jnp.zeros_like(slot_sorted).at[order].set(slot_sorted)
    return slot, flat_p, src


def moe_apply(p: dict, x: Array, *, top_k: int, n_experts: int, act: str,
              capacity_factor: float = 1.25, ep: bool | None = None) -> Array:
    """x (B, S, d) -> (B, S, d); every (batch) group routed independently.

    Structure: vmapped index-ops (route/scatter/combine stay group-local) with
    *global* expert einsums in between, so the EP resharding — tokens
    batch-sharded -> (batch x expert)-sharded — is a single annotated
    all-to-all over the model axis per direction (DESIGN.md §5)."""
    b, s, d = x.shape
    capacity = max(8, int(s * top_k * capacity_factor / n_experts))
    if ep is None:
        ep = n_experts % 16 == 0

    def route_and_pack(xg: Array):
        slot, gate, src = _route_group(xg, p["router"], top_k, capacity, n_experts)
        buf = jnp.zeros((n_experts * capacity + 1, d), xg.dtype).at[slot].set(xg[src])
        return buf[:-1], slot, gate, src

    buf, slot, gate, src = jax.vmap(route_and_pack)(x)
    # pin the scatter output to batch sharding BEFORE any reshape — without
    # this the SPMD partitioner replicates the dispatch buffer and
    # all-reduces it every layer (~14 GB/layer/device at llama4 scale; see
    # EXPERIMENTS.md §Perf iteration 1)
    buf = _shard3(buf)
    eb = buf.reshape(b, n_experts, capacity, d)
    eb = _shard4(eb, ep)  # EP: all-to-all tokens over the model axis
    if "w_gate" in p:
        h = act_fn(act, jnp.einsum("becd,edf->becf", eb, p["w_gate"])) * jnp.einsum(
            "becd,edf->becf", eb, p["w_up"])
    else:
        h = act_fn(act, jnp.einsum("becd,edf->becf", eb, p["w_up"]))
    out_e = jnp.einsum("becf,efd->becd", h, p["w_down"])
    out_e = _shard4(out_e, ep=False)  # return tokens to pure batch sharding
    out_e = out_e.reshape(b, n_experts * capacity, d)
    out_e = jnp.concatenate([out_e, jnp.zeros((b, 1, d), out_e.dtype)], axis=1)
    out_e = _shard3(out_e)

    def combine(xg, oe, sl, gt, sr):
        contrib = oe[sl] * gt[:, None].astype(oe.dtype)  # (S*k, d)
        return jnp.zeros_like(xg).at[sr].add(contrib)

    out = _shard3(jax.vmap(combine)(x, out_e, slot, gate, src))
    if "shared" in p:
        from .layers import mlp_apply

        out = out + mlp_apply(p["shared"], x, act)
    return out


def _shard4(t: Array, ep: bool) -> Array:
    from ..sharding.rules import shard

    return shard(t, "batch", "model" if ep else None, None, None)


def _shard3(t: Array) -> Array:
    from ..sharding.rules import shard

    return shard(t, *(("batch",) + (None,) * (t.ndim - 1)))
