"""Calibrate the ``default_backend()`` selection thresholds by measurement.

Usage (from the repo root):

    PYTHONPATH=src python tools/autotune_backend.py [--devices N] [--json PATH]

Sweeps the kernel-operator backends over a geometric row grid with the
benchmark suite's representative contraction (the FALKON CG quadratic op —
build + one application, the shape that dominates every fit) and reports,
per backend pair, the smallest n where the contender beats the incumbent:

  * jnp vs pallas     -> REPRO_PALLAS_MIN_ROWS   (only meaningful on TPU;
                         interpret mode never crosses over, reported as such)
  * jnp vs sharded    -> REPRO_SHARD_MIN_ROWS    (needs > 1 device; use
                         --devices N to probe with N host-platform devices)
  * device vs stream  -> REPRO_STREAM_MIN_ROWS   (the stream backend trades
                         tile-loop overhead for out-of-core capacity; its
                         threshold is a *memory* bound, so the probe reports
                         the overhead ratio at the largest in-core n plus the
                         n where X + one (n, M) tile would exceed --mem-gb)

Prints ready-to-paste ``export REPRO_*_MIN_ROWS=...`` lines; the baked-in
defaults in ``src/repro/core/backend.py`` came from this probe on the
reference CPU container. See docs/backends.md ("Selection") for how the
thresholds are consumed.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time


def _parse() -> argparse.Namespace:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--devices", type=int, default=0,
                    help="force N host-platform devices (XLA flag; probes "
                         "the sharded backend on CPU)")
    ap.add_argument("--sizes", default="512,2048,8192,32768,131072",
                    help="comma-separated row grid")
    ap.add_argument("--m", type=int, default=512, help="center count M")
    ap.add_argument("--d", type=int, default=10, help="feature dim")
    ap.add_argument("--repeats", type=int, default=3,
                    help="timed runs per point; median reported")
    ap.add_argument("--mem-gb", type=float, default=8.0,
                    help="device memory budget the stream threshold protects")
    ap.add_argument("--json", default=None, help="also dump raw timings")
    return ap.parse_args()


ARGS = _parse()
if ARGS.devices > 1:  # must precede the jax import
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + f" --xla_force_host_platform_device_count={ARGS.devices}")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.core import JnpBackend, PallasBackend, ShardedBackend, make_kernel  # noqa: E402
from repro.stream import ChunkStore, StreamBackend  # noqa: E402


def _time_quadratic(backend, kern, x, z, v, repeats: int) -> float:
    """Median seconds for (build the CG quadratic op, apply it once) —
    the per-iteration unit of a FALKON fit."""

    def run():
        out = backend.knm_quadratic(kern, x, z)(v)
        jax.block_until_ready(out)

    run()  # warmup / compile
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        run()
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


def _crossover(grid: list[int], incumbent: list[float],
               contender: list[float]) -> int | None:
    """Smallest n from which the contender stays faster; None if never."""
    for i, n in enumerate(grid):
        if all(c < b for c, b in zip(contender[i:], incumbent[i:])):
            return n
    return None


def main() -> None:
    sizes = [int(s) for s in ARGS.sizes.split(",")]
    kern = make_kernel("gaussian", sigma=2.0)
    rng = np.random.default_rng(0)
    m, d = ARGS.m, ARGS.d
    z = jnp.asarray(rng.standard_normal((m, d), dtype=np.float32))
    v = jnp.ones((m,), jnp.float32)

    backends: dict[str, object] = {"jnp": JnpBackend(), "pallas": PallasBackend()}
    if len(jax.devices()) > 1:
        backends["sharded"] = ShardedBackend()
    else:
        print("# single device: sharded not probed (rerun with --devices N)")
    backends["stream"] = StreamBackend()

    timings: dict[str, list[float]] = {k: [] for k in backends}
    for n in sizes:
        xh = rng.standard_normal((n, d), dtype=np.float32)
        xd = jnp.asarray(xh)
        for name, be in backends.items():
            x = ChunkStore(xh) if name == "stream" else xd
            t = _time_quadratic(be, kern, x, z, v, ARGS.repeats)
            timings[name].append(t)
            print(f"n={n:>8}  {name:<8} {t * 1e3:9.2f} ms", flush=True)

    print()
    on_tpu = jax.default_backend() == "tpu"
    cross_p = _crossover(sizes, timings["jnp"], timings["pallas"])
    if cross_p is not None and on_tpu:
        print(f"export REPRO_PALLAS_MIN_ROWS={cross_p}")
    else:
        why = "interpret mode" if not on_tpu else "no crossover on this grid"
        print(f"# pallas never beats jnp here ({why}); REPRO_PALLAS_MIN_ROWS "
              "only matters on real TPU")
    if "sharded" in timings:
        cross_s = _crossover(sizes, timings["jnp"], timings["sharded"])
        if cross_s is not None:
            print(f"export REPRO_SHARD_MIN_ROWS={cross_s}")
        else:
            print("# sharded never beats jnp on this grid; raise --sizes or "
                  "keep the baked-in default")
    # stream: a capacity threshold, not a speed crossover — report the
    # overhead it costs and the n where in-core stops being an option.
    ratio = timings["stream"][-1] / timings["jnp"][-1]
    # in-core cost per row: the X row itself plus one K_nM tile row
    n_mem = int(ARGS.mem_gb * 1e9 / (4 * (d + m)))
    print(f"# stream overhead at n={sizes[-1]}: {ratio:.2f}x the in-core jnp "
          "path (tile-loop + H2D)")
    print(f"export REPRO_STREAM_MIN_ROWS={1 << (n_mem - 1).bit_length() >> 1}"
          f"  # ~{ARGS.mem_gb:g} GB budget: X+(tile,M) rows ~"
          f" {4 * (d + m)} B/row -> n ~ {n_mem:.2e}")
    if ARGS.json:
        with open(ARGS.json, "w") as f:
            json.dump({"sizes": sizes, "timings": timings,
                       "m": m, "d": d}, f, indent=1)
        print(f"# wrote {ARGS.json}", file=sys.stderr)


if __name__ == "__main__":
    main()
