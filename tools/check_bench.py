#!/usr/bin/env python3
"""Perf-regression gate: diff a fresh benchmark JSON against the checked-in
baseline trajectory.

Usage (CI, after the bench-smoke run):

    python tools/check_bench.py bench_smoke.json --smoke --report diff.md

Compares every row shared by the two files and fails (exit 1) when either

  * the **median** of the per-row ratios exceeds ``--median-max`` (broad
    slowdown), or
  * any single row's ratio exceeds ``--row-max`` (one subsystem regressed;
    sized to tolerate the documented run-to-run bounce of the noisiest
    rows).

Two comparison modes:

  * ``normalized`` (default, what CI uses): the runner-speed difference
    between the machine that recorded the baseline and the CI runner is
    estimated as the **median of the per-row ratios** (robust while fewer
    than half the rows regress), and each row is gated on its ratio
    divided by that estimate. The median gate still applies to the *raw*
    median ratio, so a broad slowdown across every row is caught too —
    set ``--median-max`` loose enough to absorb the expected runner
    spread.
  * ``absolute``: per-row gates use the raw microsecond ratios; only
    meaningful when baseline and current were recorded on comparable
    machines (local use).

Rows where both sides are below ``--abs-floor-us`` are skipped: timings
that small are dispatch-jitter, not signal.

The baseline is the newest ``BENCH_PR*.json`` in the repo root (or
``BENCH_PR*_SMOKE.json`` with ``--smoke``, matching the smoke-sized rows
the CI bench job produces); ``--baseline`` overrides the search.
"""
from __future__ import annotations

import argparse
import json
import re
import statistics
import sys
from pathlib import Path

_BASELINE_RE = re.compile(r"BENCH_PR(\d+)\.json$")
_BASELINE_SMOKE_RE = re.compile(r"BENCH_PR(\d+)_SMOKE\.json$")


def load_rows(path: str | Path) -> dict[str, float]:
    """name -> us_per_call from a benchmarks/run.py --json file."""
    with open(path) as f:
        records = json.load(f)
    return {r["name"]: float(r["us_per_call"]) for r in records}


def find_baseline(root: str | Path = ".", *, smoke: bool = False) -> Path:
    """Newest BENCH_PR<k>[_SMOKE].json by PR number (not mtime: checkouts
    do not preserve it)."""
    rx = _BASELINE_SMOKE_RE if smoke else _BASELINE_RE
    best: tuple[int, Path] | None = None
    for p in Path(root).glob("BENCH_PR*.json"):
        m = rx.search(p.name)
        if m and (best is None or int(m.group(1)) > best[0]):
            best = (int(m.group(1)), p)
    if best is None:
        kind = "BENCH_PR*_SMOKE.json" if smoke else "BENCH_PR*.json"
        raise FileNotFoundError(f"no {kind} baseline found under {root!r}")
    return best[1]


def compare(
    current: dict[str, float],
    baseline: dict[str, float],
    *,
    mode: str = "normalized",
    median_max: float = 1.6,
    row_max: float = 3.0,
    abs_floor_us: float = 5000.0,
) -> tuple[bool, list[str]]:
    """Return (ok, report_lines). ``ok`` is False on any gate violation."""
    common = sorted(set(current) & set(baseline))
    lines = [
        f"mode={mode} median_max={median_max} row_max={row_max} "
        f"abs_floor_us={abs_floor_us:g}",
        f"{len(common)} shared rows "
        f"({len(current) - len(common)} only-current, "
        f"{len(baseline) - len(common)} only-baseline)",
    ]
    if not common:
        lines.append("FAIL: no shared rows between current and baseline")
        return False, lines

    kept = [n for n in common
            if max(current[n], baseline[n]) >= abs_floor_us]
    skipped = [n for n in common if n not in kept]
    if skipped:
        lines.append(f"skipped {len(skipped)} sub-floor rows: "
                     + ", ".join(skipped))
    if not kept:
        lines.append("OK: every shared row is below the jitter floor")
        return True, lines

    raw = {n: current[n] / baseline[n] for n in kept}
    med = statistics.median(raw.values())
    scale = med if mode == "normalized" else 1.0
    gated = {n: r / scale for n, r in raw.items()}

    lines.append(f"{'row':40s} {'base_us':>12s} {'cur_us':>12s} "
                 f"{'abs_ratio':>10s} {'gated':>10s}")
    for n in kept:
        lines.append(f"{n:40s} {baseline[n]:12.1f} {current[n]:12.1f} "
                     f"{raw[n]:10.2f} {gated[n]:10.2f}")

    ok = True
    lines.append(f"median raw ratio: {med:.3f} (max {median_max})")
    if med > median_max:
        lines.append(f"FAIL: median ratio {med:.3f} > {median_max}")
        ok = False
    worst_name = max(gated, key=gated.get)
    worst = gated[worst_name]
    lines.append(f"worst gated row: {worst_name} at {worst:.3f} "
                 f"(max {row_max})")
    if worst > row_max:
        lines.append(f"FAIL: row {worst_name} ratio {worst:.3f} > {row_max}")
        ok = False
    if ok:
        lines.append("OK: within regression bounds")
    return ok, lines


def check_online_speedup(
    current: dict[str, float], min_speedup: float,
) -> tuple[bool, list[str]]:
    """Gate the durable-online-FALKON promise: a warm ``refit()`` after an
    append must beat a cold from-scratch fit on the same rows by at least
    ``min_speedup`` (absolute within one JSON, so runner speed cancels)."""
    cold = current.get("online.cold_refit")
    warm = current.get("online.warm_refit")
    if cold is None or warm is None:
        return False, ["FAIL: online-min-speedup gate needs both "
                       "online.cold_refit and online.warm_refit rows "
                       "(run benchmarks/run.py --only online)"]
    speedup = cold / warm
    line = (f"online warm-refit speedup: {speedup:.1f}x "
            f"(cold {cold:.0f}us / warm {warm:.0f}us, min {min_speedup:g}x)")
    if speedup < min_speedup:
        return False, [line, f"FAIL: warm refit only {speedup:.1f}x faster "
                             f"than cold (< {min_speedup:g}x)"]
    return True, [line]


def main(argv: list[str] | None = None) -> int:
    """CLI entry point; returns the process exit code."""
    ap = argparse.ArgumentParser(description=__doc__.split("\n", 1)[0])
    ap.add_argument("current", help="fresh benchmarks/run.py --json output")
    ap.add_argument("--baseline", default=None,
                    help="baseline JSON (default: newest BENCH_PR*.json)")
    ap.add_argument("--root", default=Path(__file__).resolve().parent.parent,
                    help="where to search for the baseline (repo root)")
    ap.add_argument("--smoke", action="store_true",
                    help="baseline search targets BENCH_PR*_SMOKE.json "
                         "(rows recorded at CI smoke sizes)")
    ap.add_argument("--mode", choices=["normalized", "absolute"],
                    default="normalized")
    ap.add_argument("--median-max", type=float, default=1.6)
    ap.add_argument("--row-max", type=float, default=3.0)
    ap.add_argument("--abs-floor-us", type=float, default=5000.0)
    ap.add_argument("--report", default=None,
                    help="also write the report to this path (CI artifact)")
    ap.add_argument("--online-min-speedup", type=float, default=0.0,
                    help="if > 0, additionally require the current JSON's "
                         "online.cold_refit / online.warm_refit ratio to be "
                         "at least this (the durable-online-FALKON promise; "
                         "absolute, baseline-independent)")
    args = ap.parse_args(argv)

    baseline_path = args.baseline or find_baseline(args.root, smoke=args.smoke)
    current_rows = load_rows(args.current)
    ok, lines = compare(
        current_rows, load_rows(baseline_path),
        mode=args.mode, median_max=args.median_max, row_max=args.row_max,
        abs_floor_us=args.abs_floor_us)
    if args.online_min_speedup > 0:
        ok2, lines2 = check_online_speedup(current_rows,
                                           args.online_min_speedup)
        ok, lines = ok and ok2, lines + lines2
    report = "\n".join([f"baseline: {baseline_path}", *lines])
    print(report)
    if args.report:
        Path(args.report).write_text(report + "\n")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
