#!/usr/bin/env python
"""Markdown link checker for the docs site (no third-party deps).

Validates every ``[text](target)`` link in the given markdown files:

  * relative file targets must exist (resolved against the file's directory);
  * ``#fragment`` anchors must match a heading in the target file, using
    GitHub's slug rules (lowercase, spaces -> '-', punctuation dropped);
  * ``http(s)://`` / ``mailto:`` targets are skipped (CI has no network).

Usage:  python tools/check_docs_links.py README.md DESIGN.md docs/*.md
Exit status 0 when every link resolves, 1 with a per-link report otherwise.
Used by the CI ``docs-check`` job and by tests/test_docs.py.
"""
from __future__ import annotations

import pathlib
import re
import sys

# [text](target) — target has no spaces/parens (our docs use plain targets);
# images ![alt](src) are matched too (the leading ! is irrelevant here).
_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_HEADING = re.compile(r"^#{1,6}\s+(.*)$", re.MULTILINE)
_CODE_FENCE = re.compile(r"```.*?```", re.DOTALL)


def github_slug(heading: str) -> str:
    """GitHub's anchor slug: lowercase; spaces to '-'; drop everything that
    is not alphanumeric, hyphen, or underscore (so '§2.4 Fused fit' ->
    '24-fused-fit')."""
    s = heading.strip().lower().replace(" ", "-")
    return re.sub(r"[^0-9a-zÀ-￿_-]", "", s)


def anchors_of(path: pathlib.Path) -> set[str]:
    """All heading slugs of a markdown file, with GitHub's duplicate rule:
    the first occurrence keeps the bare slug, the n-th gets ``-{n-1}``."""
    text = _CODE_FENCE.sub("", path.read_text())
    slugs: list[str] = []
    seen: dict[str, int] = {}
    for match in _HEADING.finditer(text):
        base = github_slug(match.group(1))
        n = seen.get(base, 0)
        seen[base] = n + 1
        slugs.append(base if n == 0 else f"{base}-{n}")
    return set(slugs)


def check_file(path: pathlib.Path) -> list[str]:
    """Return one error string per broken link in ``path``."""
    errors: list[str] = []
    text = _CODE_FENCE.sub("", path.read_text())
    for match in _LINK.finditer(text):
        target = match.group(1)
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        file_part, _, fragment = target.partition("#")
        dest = path if not file_part else (path.parent / file_part).resolve()
        if not dest.exists():
            errors.append(f"{path}: broken link -> {target} (no such file)")
            continue
        if fragment and dest.suffix == ".md":
            if github_slug(fragment) not in anchors_of(dest):
                errors.append(f"{path}: broken anchor -> {target}")
    return errors


def main(argv: list[str]) -> int:
    """CLI entry point: check every argv path, print a report, 0/1 exit."""
    if not argv:
        print("usage: check_docs_links.py FILE.md [FILE.md ...]", file=sys.stderr)
        return 2
    errors: list[str] = []
    for name in argv:
        p = pathlib.Path(name)
        if not p.exists():
            errors.append(f"{name}: file not found")
            continue
        errors.extend(check_file(p))
    for e in errors:
        print(e, file=sys.stderr)
    print(f"checked {len(argv)} files: "
          f"{'OK' if not errors else f'{len(errors)} broken links'}")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
