#!/usr/bin/env python3
"""Gate the mask-panel tax: scenarios.quad_masked <= --max-ratio x
scenarios.quad_unmasked, read from one benchmarks/run.py --json file.

Both rows are timed back-to-back in the same process on the same data
(see ``bench_scenarios``), so the ratio cancels runner speed — unlike the
cross-run ratios ``check_bench.py`` gates. This is the PR 9 acceptance
bound on the exact-CV mechanism: the per-column row mask must cost one
elementwise multiply per tile, not a second pass over the kernel
evaluations.

Usage (CI, after the bench-smoke run):

    python tools/check_mask_tax.py bench_smoke.json --max-ratio 1.15
"""
from __future__ import annotations

import argparse
import json
import sys

UNMASKED = "scenarios.quad_unmasked"
MASKED = "scenarios.quad_masked"


def check(path: str, max_ratio: float) -> int:
    """Return the process exit code (0 = within the gate)."""
    with open(path) as f:
        rows = {r["name"]: float(r["us_per_call"]) for r in json.load(f)}
    missing = [n for n in (UNMASKED, MASKED) if n not in rows]
    if missing:
        print(f"FAIL: {path} has no {' / '.join(missing)} row(s); "
              "was the scenarios bench in the --only list?")
        return 1
    ratio = rows[MASKED] / rows[UNMASKED]
    print(f"{MASKED} / {UNMASKED} = {rows[MASKED]:.1f} / "
          f"{rows[UNMASKED]:.1f} us = {ratio:.3f} (max {max_ratio})")
    if ratio > max_ratio:
        print(f"FAIL: mask-panel tax {ratio:.3f} > {max_ratio}")
        return 1
    print("OK: mask multiply within the per-tile budget")
    return 0


def main(argv: list[str] | None = None) -> int:
    """CLI entry point; returns the process exit code."""
    ap = argparse.ArgumentParser(description=__doc__.split("\n", 1)[0])
    ap.add_argument("current", help="fresh benchmarks/run.py --json output")
    ap.add_argument("--max-ratio", type=float, default=1.15)
    args = ap.parse_args(argv)
    return check(args.current, args.max_ratio)


if __name__ == "__main__":
    sys.exit(main())
