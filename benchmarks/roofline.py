"""Render EXPERIMENTS.md roofline tables from exp/dryrun/*.json.

    PYTHONPATH=src python -m benchmarks.roofline [--dir exp/dryrun]
"""
from __future__ import annotations

import argparse
import glob
import json
import os

NOTE = {
    "compute": "raise useful-FLOP ratio (remat policy / head-padding / capacity waste)",
    "memory": "cut bytes/step: weight reuse across tokens (batching) or compressed KV",
    "collective": "reshard to kill all-gather/all-reduce volume; overlap with compute",
}


def load(dirname: str):
    rows = [json.load(open(f)) for f in sorted(glob.glob(os.path.join(dirname, "*.json")))]
    return ([r for r in rows if r["status"] == "ok"],
            [r for r in rows if r["status"] == "skipped"],
            [r for r in rows if r["status"] == "failed"])


def fmt_table(rows, mesh: str) -> str:
    hdr = ("| arch | shape | t_compute (s) | t_memory (s) | t_collective (s) | "
           "bottleneck | MODEL_FLOPS | useful ratio | roofline frac | peak mem/dev (GiB) |\n"
           "|---|---|---|---|---|---|---|---|---|---|\n")
    out = [hdr]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        if r["mesh"] != mesh:
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']:.4f} | "
            f"{r['t_memory_s']:.4f} | {r['t_collective_s']:.4f} | "
            f"**{r['bottleneck']}** | {r['model_flops']:.2e} | "
            f"{r['useful_ratio']:.3f} | {r['roofline_fraction']:.3f} | "
            f"{r['peak_mem_gb']:.1f} |\n")
    return "".join(out)


def fmt_notes(rows, mesh: str) -> str:
    out = []
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        if r["mesh"] != mesh:
            continue
        out.append(f"- **{r['arch']} x {r['shape']}** — {r['bottleneck']}-bound; "
                   f"to move the dominant term: {NOTE[r['bottleneck']]}.\n")
    return "".join(out)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="exp/dryrun")
    ap.add_argument("--notes", action="store_true")
    args = ap.parse_args()
    ok, sk, fail = load(args.dir)
    print(f"### Single-pod (16x16 = 256 chips)\n\n{fmt_table(ok, '16x16')}")
    print(f"\n### Multi-pod (2x16x16 = 512 chips)\n\n{fmt_table(ok, '2x16x16')}")
    if args.notes:
        print("\n### Per-cell notes\n\n" + fmt_notes(ok, "16x16"))
    print("\n### Skipped cells\n")
    for r in sorted(sk, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        print(f"- {r['arch']} x {r['shape']} x {r['mesh']}: {r['reason']}")
    if fail:
        print("\n### FAILED\n")
        for r in fail:
            print(f"- {r['arch']} x {r['shape']} x {r['mesh']}: {r['error']}")


if __name__ == "__main__":
    main()
