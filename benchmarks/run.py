"""Benchmark harness — one function per paper table/figure.

  bench_fig1_raccuracy        Fig. 1: R-ACC of approximate leverage scores
  bench_fig2_runtime_scaling  Fig. 2: runtime vs n (BLESS ~flat in n)
  bench_table1_complexity     Table 1: |J| ~ d_eff(lam), runtime ~ 1/lam
  bench_fig3_lambda_stability Fig. 3: error across lam_falkon grid
  bench_fig45_falkon          Fig. 4/5: FALKON-BLESS vs FALKON-UNI per iter
  bench_multi_rhs             multi-RHS block-CG: k outputs / CV folds in
                              one solve vs the per-column loop
  bench_scenarios             scenario layer: mask-panel tax on the quad op
                              (exact CV), classifier fit, variance scorer
  bench_bigk                  out-of-core: million-row FALKON through the
                              stream backend, peak device bytes recorded
  bench_online                durable online FALKON: append + warm refit
                              vs cold fit (the >=5x CI speedup gate)
  bench_lm_steps              framework: smoke-scale train/decode step times

Prints ``name,us_per_call,derived`` CSV rows (stdout), one per measurement.
CPU-scale sizes; every timing is post-warmup (jit cache hot).

Flags:
  --backend {jnp,pallas,sharded,stream}  pin the kernel-operator backend
  --json PATH      also write the records as a JSON array (the perf
                   trajectory artifact future perf PRs diff against)
  --repeats N      time each measurement N times, report the median
  --only A,B       run only benches whose registry name contains a substring
  --smoke          tiny sizes (CI smoke job: fast, still end-to-end)
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import (BlessRSampler, BlessSampler, ChenYangSampler,
                       FalkonRegressor, FitConfig, KFoldSweep,
                       RecursiveRlsSampler, SqueakSampler, UniformSampler,
                       make_kernel)
from repro.core import exact_rls, falkon_fit
from repro.core.leverage import approx_rls_all

_RECORDS: list[dict] = []
_REPEATS = 1


def emit(name: str, us: float, derived: str = "") -> None:
    _RECORDS.append({"name": name, "us_per_call": round(us, 1), "derived": derived})
    print(f"{name},{us:.1f},{derived}", flush=True)


def _ready(out) -> None:
    if hasattr(out, "final"):
        jax.block_until_ready(out.final.centers.idx)
    elif hasattr(out, "idx"):
        jax.block_until_ready(out.idx)
    elif hasattr(out, "alpha"):
        jax.block_until_ready(out.alpha)
    else:
        jax.block_until_ready(out)


def timed(fn):
    """(last result, median us over --repeats runs), after one warmup call."""
    _ready(fn())  # warmup: compile every shape this measurement touches
    times = []
    out = None
    for _ in range(_REPEATS):
        t0 = time.perf_counter()
        out = fn()
        _ready(out)
        times.append((time.perf_counter() - t0) * 1e6)
    return out, float(np.median(times))


def _data(n: int, d: int = 10, seed: int = 0, clusters: int = 12):
    key = jax.random.PRNGKey(seed)
    kc, ka, kn = jax.random.split(key, 3)
    centers = jax.random.normal(kc, (clusters, d)) * 3.0
    assign = jax.random.randint(ka, (n,), 0, clusters)
    return centers[assign] + 0.5 * jax.random.normal(kn, (n, d))


def _classif(n: int, n_test: int, d: int = 8, seed: int = 1):
    """One ground-truth rule; train/test split from the same distribution."""
    key = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(key, 3)
    x = jax.random.normal(k1, (n + n_test, d))
    w = jax.random.normal(k2, (d,))
    margin = jnp.tanh(x @ w + 0.7 * jnp.sin(2 * x[:, 0]) * x[:, 1])
    y = jnp.sign(margin + 0.3 * jax.random.normal(k3, (n + n_test,)))
    y = jnp.where(y == 0, 1.0, y)
    return x[:n], y[:n], x[n:], y[n:]


def _racc_stats(scores, ell):
    r = np.asarray(scores / ell)
    return (float(r.mean()), float(np.quantile(r, 0.05)), float(np.quantile(r, 0.95)))


def bench_fig1_raccuracy(n: int = 2000, lam: float = 1e-3, backend=None) -> None:
    """Every method is a repro.api Sampler: one CenterSet contract, one
    scoring path (Eq. 3 at the target lam), apples-to-apples."""
    x = _data(n)
    kern = make_kernel("gaussian", sigma=2.0)
    ell = exact_rls(kern, x, lam)
    key = jax.random.PRNGKey(0)
    lamj = jnp.asarray(lam)

    def run(tag, sampler):
        cs, us = timed(lambda: sampler.sample(key, x, kern, backend=backend))
        m, q5, q95 = _racc_stats(approx_rls_all(kern, x, cs, lamj, backend=backend), ell)
        emit(f"fig1.{tag}", us, f"racc={m:.3f};q5={q5:.2f};q95={q95:.2f};M={int(cs.count)}")
        return int(cs.count)

    run("bless", BlessSampler(lam=lam, q2=4.0, q1=4.0))
    mref = run("bless_r", BlessRSampler(lam=lam, q2=4.0))
    run("squeak", SqueakSampler(lam=lam, m_cap=mref))
    run("rrls", RecursiveRlsSampler(lam=lam, m_cap=mref))
    run("chen_yang", ChenYangSampler(m=mref, lam=lam))
    run("uniform", UniformSampler(m=mref))


def bench_fig2_runtime_scaling(lam: float = 2e-3, backend=None,
                               sizes=(1000, 2000, 4000, 8000)) -> None:
    key = jax.random.PRNGKey(0)
    kern = make_kernel("gaussian", sigma=2.0)
    samplers = (
        ("bless", BlessSampler(lam=lam, q2=3.0, q1=3.0)),
        ("squeak", SqueakSampler(lam=lam, m_cap=600)),
        ("rrls", RecursiveRlsSampler(lam=lam, m_cap=600)),
    )
    for n in sizes:
        x = _data(n)
        for name, sampler in samplers:
            _, us = timed(lambda: sampler.sample(key, x, kern, backend=backend))
            emit(f"fig2.{name}.n{n}", us, f"n={n}")


def bench_table1_complexity(n: int = 2000, backend=None) -> None:
    """|J_H| tracks q2*d_eff(lam) across lam — the Table 1 / Thm 1(b) claim."""
    x = _data(n)
    kern = make_kernel("gaussian", sigma=2.0)
    key = jax.random.PRNGKey(0)
    q2 = 3.0
    for lam in (1e-2, 3e-3, 1e-3):
        deff = float(jnp.sum(exact_rls(kern, x, lam)))
        sampler = BlessSampler(lam=lam, q2=q2, q1=3.0)
        res, us = timed(lambda: sampler.ladder(key, x, kern, backend=backend))
        emit(f"table1.lam{lam:g}", us,
             f"deff={deff:.1f};M={res.final.m_h};q2*deff={q2 * deff:.1f};H={len(res.levels)}")


def bench_fig45_falkon(n: int = 3000, m_target: int = 250, n_test: int = 800,
                       backend=None) -> None:
    """Error per CG iteration: BLESS centers+weights vs uniform centers.
    Same estimator slot, two samplers — the api's swap-the-sampler story."""
    x, y, xte, yte = _classif(n, n_test)
    kern = make_kernel("gaussian", sigma=2.0)
    lam_falkon, lam_bless = 1e-5, 1e-3

    cs_bless = BlessSampler(lam=lam_bless, q2=3.0, m_cap=m_target).sample(
        jax.random.PRNGKey(0), x, kern, backend=backend)
    mh = int(cs_bless.count)
    cs_uni = UniformSampler(m=mh, replace=False, weights="identity").sample(
        jax.random.PRNGKey(1), x, kern)

    def err_curve(cs, tag):
        est = FalkonRegressor(kernel=kern,
                              config=FitConfig(lam=lam_falkon, iters=20,
                                               backend=backend))

        def run():
            errs = []

            def cb(i, model):
                pred = jnp.sign(model.predict(xte))
                errs.append(float(jnp.mean(pred != yte)))

            est.fit(x, y, center_set=cs, callback=cb)
            return errs

        errs, us = timed(run)
        best5 = min(errs[:5])
        emit(f"fig45.{tag}", us, f"err@5={best5:.4f};err@20={errs[-1]:.4f};M={mh}")

    err_curve(cs_bless, "falkon_bless")
    err_curve(cs_uni, "falkon_uni")


def bench_fig3_lambda_stability(n: int = 2000, m_cap: int = 250, n_test: int = 600,
                                backend=None) -> None:
    """Lambda sweep on fixed centers — warm-start refits riding the fused-fit
    jit cache (lam is traced: every lam after the first is a cache hit)."""
    x, y, xte, yte = _classif(n, n_test)
    kern = make_kernel("gaussian", sigma=2.0)
    cs_bless = BlessSampler(lam=1e-3, q2=3.0, m_cap=m_cap).sample(
        jax.random.PRNGKey(0), x, kern, backend=backend)
    mh = int(cs_bless.count)
    cs_uni = UniformSampler(m=mh, replace=False, weights="identity").sample(
        jax.random.PRNGKey(1), x, kern)
    ests = {tag: FalkonRegressor(kernel=kern, warm_start=True,
                                 config=FitConfig(lam=1e-3, iters=5, backend=backend))
            for tag in ("bless", "uni")}
    ests["bless"].fit(x, y, center_set=cs_bless)  # installs the centers
    ests["uni"].fit(x, y, center_set=cs_uni)
    for lam in (1e-3, 1e-5, 1e-7):
        for tag, est in ests.items():
            est.config = FitConfig(lam=lam, iters=5, backend=backend)
            _, us = timed(lambda: est.fit(x, y))  # warm start: centers reused
            err = float(jnp.mean(jnp.sign(est.predict(xte)) != yte))
            emit(f"fig3.{tag}.lam{lam:g}", us, f"cerr@5it={err:.4f}")


def bench_multi_rhs(n: int = 3000, m: int = 256, k: int = 8, folds: int = 4,
                    iters: int = 20, backend=None) -> None:
    """Multi-RHS block-CG amortization: k outputs (or CV folds) share the
    preconditioner and the K_nM streaming, so fused_k{k} should sit far
    below k x fused_k1 while loop_k{k} (the pre-PR 4 column loop, the
    honest baseline) pays the full k x."""
    x = _data(n)
    kern = make_kernel("gaussian", sigma=2.0)
    key = jax.random.PRNGKey(0)
    cs = UniformSampler(m=m, replace=False, weights="identity").sample(key, x, kern)
    centers = x[cs.idx[:m]]
    cols = [jnp.sin((j + 2) * x[:, j % x.shape[1]]) + 0.1 * j for j in range(k)]
    ymulti = jnp.stack(cols, axis=1)
    lam = 1e-5

    _, us1 = timed(lambda: falkon_fit(kern, x, ymulti[:, 0], centers, lam,
                                      iters=iters, backend=backend))
    emit("multi_rhs.fused_k1", us1, f"n={n};M={m};iters={iters}")
    _, usk = timed(lambda: falkon_fit(kern, x, ymulti, centers, lam,
                                      iters=iters, backend=backend))
    emit(f"multi_rhs.fused_k{k}", usk, f"k={k};xk1={usk / us1:.2f}")

    def column_loop():
        return [falkon_fit(kern, x, ymulti[:, j], centers, lam, iters=iters,
                           backend=backend).alpha for j in range(k)]

    _, usl = timed(lambda: jnp.stack(column_loop(), axis=1))
    emit(f"multi_rhs.loop_k{k}", usl, f"k={k};xk1={usl / us1:.2f}")

    lams = (1e-3, 1e-5, 1e-7)
    sweep = KFoldSweep(kernel=kern, lams=lams, folds=folds, iters=iters,
                       backend=backend)
    y1 = ymulti[:, 0]
    # time the scores array so _ready() blocks on real compute (KFoldResult
    # itself is an unregistered dataclass jax cannot block on)
    _, usf = timed(lambda: sweep.run(x, y1, center_set=cs).scores)
    emit("multi_rhs.kfold", usf,
         f"lams={len(lams)};folds={folds};solves={len(lams)};"
         f"fits_naive={len(lams) * folds}")


def bench_scenarios(n: int = 3000, m: int = 256, k: int = 8, iters: int = 15,
                    n_quad: int | None = None, backend=None) -> None:
    """PR 9 scenario layer: the mask-panel tax on the streamed quadratic op
    (the exact-CV mechanism — gate: masked <= 1.15x unmasked), one-vs-rest
    classification as one panel solve, and the predictive-variance scorer.
    The quad pair is timed back-to-back in one process, so the ratio in the
    derived field is runner-speed independent; ``n_quad`` sizes that pair
    separately so the smoke run keeps its timings above dispatch jitter."""
    from repro.api import FalkonClassifier
    from repro.core import resolve_backend

    kern = make_kernel("gaussian", sigma=2.0)
    key = jax.random.PRNGKey(0)
    nq = n_quad if n_quad is not None else n
    xq = _data(nq)
    be = resolve_backend(backend, n=nq)
    centers = xq[:m]
    v = jax.random.normal(key, (m, k))
    mask = (jax.random.uniform(key, (nq, k)) > 0.25).astype(jnp.float32)

    # jit the ops as the fused fit does — the gate measures the mask
    # multiply's compute tax, not eager dispatch overhead
    quad = jax.jit(be.knm_quadratic(kern, xq, centers))
    _, us_plain = timed(lambda: quad(v))
    emit("scenarios.quad_unmasked", us_plain, f"n={nq};M={m};k={k}")
    mquad = jax.jit(be.knm_quadratic(kern, xq, centers, mask=mask))
    _, us_mask = timed(lambda: mquad(v))
    emit("scenarios.quad_masked", us_mask,
         f"n={nq};M={m};k={k};ratio={us_mask / us_plain:.3f};gate=1.15")

    xtr, ytr, xte, yte = _classif(n, max(200, n // 5))
    labels = np.asarray(jnp.where(ytr > 0, 1, 0))
    clf = FalkonClassifier(kernel=kern, sampler=UniformSampler(m=m),
                           config=FitConfig(lam=1e-5, iters=iters,
                                            backend=backend),
                           warm_start=True)

    def fit_clf():
        clf.fit(xtr, labels)
        return clf.model_

    _, us_fit = timed(fit_clf)
    acc = clf.score(xte, np.asarray(jnp.where(yte > 0, 1, 0)))
    emit("scenarios.classifier_fit", us_fit,
         f"n={n};M={m};classes=2;acc={acc:.4f}")

    _, us_var = timed(lambda: clf.model_.predictive_variance(xte))
    emit("scenarios.variance", us_var, f"n_test={xte.shape[0]};M={m}")


def bench_bigk(n: int = 1_000_000, m: int = 1024, d: int = 10, iters: int = 3,
               backend=None) -> None:
    """Out-of-core FALKON (DESIGN.md §10): fit + predict at n rows through
    the stream backend with X host-resident, emitting the subsystem's peak
    device bytes next to wall time. ``knmMB`` in the derived field is what a
    materialized (n, M) K_nM would cost — the peak staying orders of
    magnitude below it is the whole point. Timed once with no warmup pass:
    the wall time is streaming compute (compile is seconds against minutes),
    and a full-size warmup would double a minutes-long bench.
    """
    from repro.core import resolve_backend
    from repro.stream import (ChunkStore, StreamBackend, peak_device_bytes,
                              reset_peak_device_bytes)

    inner = "jnp" if backend in (None, "stream") else backend
    be = StreamBackend(inner=resolve_backend(inner))
    rng = np.random.default_rng(0)
    x = rng.standard_normal((n, d), dtype=np.float32)
    y = np.sin(3.0 * x[:, 0]) * np.cos(x[:, 1])
    store = ChunkStore(x, y.astype(np.float32))
    centers = store[np.linspace(0, n - 1, m).astype(np.int64)]
    kern = make_kernel("gaussian", sigma=2.0)
    knm_mb = 4.0 * n * m / 1e6

    reset_peak_device_bytes()
    t0 = time.perf_counter()
    model = falkon_fit(kern, store, jnp.asarray(y), centers, 1e-6,
                       iters=iters, backend=be)
    jax.block_until_ready(model.alpha)
    us_fit = (time.perf_counter() - t0) * 1e6
    peak_mb = peak_device_bytes() / 1e6
    emit("bigk.falkon_fit", us_fit,
         f"n={n};M={m};iters={iters};peakMB={peak_mb:.1f};knmMB={knm_mb:.0f}")

    reset_peak_device_bytes()
    t0 = time.perf_counter()
    pred = model.predict(store)
    jax.block_until_ready(pred)
    us_pred = (time.perf_counter() - t0) * 1e6
    emit("bigk.predict", us_pred,
         f"n={n};M={m};peakMB={peak_device_bytes() / 1e6:.1f};knmMB={knm_mb:.0f}")


def bench_online(n: int = 50_000, m: int = 384, iters: int = 10,
                 backend=None) -> None:
    """Durable online FALKON: absorb a fresh batch into the streamed
    normal-equation accumulators, then warm-refit — O(batch) + O(M^2·iters),
    n-independent — vs a cold from-scratch fit on the same rows. The warm
    row's speedup is the >=5x gate tools/check_bench.py enforces in CI."""
    from repro.api import OnlineFalkon

    rng = np.random.default_rng(0)
    x = rng.normal(size=(n, 10)).astype(np.float32)
    y = np.sin(2.0 * x[:, 0]).astype(np.float32)
    kern = make_kernel("gaussian", sigma=2.0)
    centers = jnp.asarray(x[:m])
    batch = n // 10
    of = OnlineFalkon(kern, centers, 1e-6, x=x[: n - batch], y=y[: n - batch],
                      iters=iters, backend=backend or "stream")
    # return the accumulator so timed() blocks on the absorbed batch
    _, us_app = timed(lambda: (of.append(x[n - batch:], y[n - batch:]),
                               of._h)[1])
    _, us_warm = timed(lambda: of.refit())
    _, us_cold = timed(lambda: falkon_fit(
        kern, jnp.asarray(x), jnp.asarray(y), centers, 1e-6, iters=iters,
        backend=backend or "stream"))
    emit("online.append", us_app, f"n={n};M={m};batch={batch}")
    emit("online.cold_refit", us_cold, f"n={n};M={m};iters={iters}")
    emit("online.warm_refit", us_warm,
         f"n={n};M={m};iters={iters};speedup={us_cold / us_warm:.1f}x")


def bench_lm_steps(backend=None) -> None:
    """Smoke-scale per-arch step timing (framework sanity, not paper)."""
    from repro.configs import get_config, list_archs, smoke
    from repro.data import TokenPipeline
    from repro.optim import OptConfig
    from repro.training import make_train_step, train_state_init

    for name in list_archs():
        cfg = smoke(get_config(name))
        state = train_state_init(cfg, jax.random.PRNGKey(0))
        step = jax.jit(make_train_step(cfg, OptConfig(), loss_chunks=4))
        pipe = TokenPipeline(cfg.vocab_size, batch=4, seq=64)
        if not cfg.embed_inputs:
            mk = lambda s: {"frames": jnp.zeros((4, 64, cfg.d_model), jnp.bfloat16),
                            "labels": pipe.batch_at(s)["labels"]}
        elif cfg.pos == "mrope":
            def mk(s):
                b = pipe.batch_at(s)
                p = jnp.broadcast_to(jnp.arange(64), (4, 64))
                b["mrope_positions"] = jnp.stack([p, p, p], 1)
                b["pixel_embeds"] = jnp.zeros((4, cfg.extra_image_tokens, cfg.d_model),
                                              jnp.bfloat16)
                return b
        else:
            mk = pipe.batch_at
        state, _ = step(state, mk(0))  # compile
        t0 = time.perf_counter()
        state, metrics = step(state, mk(1))
        jax.block_until_ready(metrics["loss"])
        emit(f"lm.train_step.{name}", (time.perf_counter() - t0) * 1e6,
             f"loss={float(metrics['loss']):.3f}")


# registry name -> (full-size call, smoke-size call)
BENCHES = {
    "fig1": (bench_fig1_raccuracy, lambda backend: bench_fig1_raccuracy(n=600, backend=backend)),
    "fig2": (bench_fig2_runtime_scaling,
             lambda backend: bench_fig2_runtime_scaling(backend=backend, sizes=(500, 1000))),
    "table1": (bench_table1_complexity,
               lambda backend: bench_table1_complexity(n=600, backend=backend)),
    "fig45": (bench_fig45_falkon,
              lambda backend: bench_fig45_falkon(n=800, m_target=120, n_test=200,
                                                 backend=backend)),
    "fig3": (bench_fig3_lambda_stability,
             lambda backend: bench_fig3_lambda_stability(n=600, m_cap=120, n_test=200,
                                                         backend=backend)),
    "multi_rhs": (bench_multi_rhs,
                  lambda backend: bench_multi_rhs(n=600, m=96, k=8, iters=12,
                                                  backend=backend)),
    "scenarios": (bench_scenarios,
                  lambda backend: bench_scenarios(n=600, m=96, k=8, iters=10,
                                                  n_quad=6000, backend=backend)),
    "bigk": (bench_bigk,
             lambda backend: bench_bigk(n=20_000, m=256, iters=3,
                                        backend=backend)),
    "online": (bench_online,
               lambda backend: bench_online(n=20_000, m=256, iters=8,
                                            backend=backend)),
    "lm": (bench_lm_steps, bench_lm_steps),
}


def main() -> None:
    global _REPEATS
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--backend",
                    choices=["auto", "jnp", "pallas", "sharded", "stream"],
                    default="auto", help="kernel-operator backend for BLESS/FALKON")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="write records as a JSON array to PATH")
    ap.add_argument("--repeats", type=int, default=1,
                    help="timed runs per measurement; the median is reported")
    ap.add_argument("--only", default=None,
                    help="comma-separated substrings of bench names to run "
                         f"(registry: {','.join(BENCHES)})")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny problem sizes (CI smoke job)")
    args = ap.parse_args()
    backend = None if args.backend == "auto" else args.backend
    _REPEATS = max(1, args.repeats)
    wanted = [w for w in (args.only or "").split(",") if w]
    for w in wanted:  # a typo'd filter must not silently bench nothing
        if not any(w in name for name in BENCHES):
            ap.error(f"--only token {w!r} matches no bench; "
                     f"valid figure names: {', '.join(sorted(BENCHES))} "
                     "(substring match, comma-separated)")
    print("name,us_per_call,derived")
    for name, (full, smoke) in BENCHES.items():
        if wanted and not any(w in name for w in wanted):
            continue
        (smoke if args.smoke else full)(backend=backend)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(_RECORDS, f, indent=1)
        print(f"# wrote {len(_RECORDS)} records -> {args.json}", flush=True)


if __name__ == "__main__":
    main()
