"""Multi-device correctness (8 forced host CPU devices, subprocess-isolated
so the rest of the suite keeps seeing 1 device)."""
import os
import subprocess
import sys
import textwrap

import pytest

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp
    from repro.core import falkon_fit, make_kernel, bless, exact_rls
    from repro.core.distributed import (data_mesh, dist_knm_quadratic,
                                        falkon_fit_distributed, shard_rows)
    assert len(jax.devices()) == 8

    key = jax.random.PRNGKey(0)
    n, d, m = 1000, 6, 100
    x = jax.random.normal(key, (n, d))
    y = jnp.sin(2 * x[:, 0])
    kern = make_kernel("gaussian", sigma=1.5)
    z = x[:m]
    mesh = data_mesh()

    # distributed matvec == local
    xs = shard_rows(mesh, x)
    op = dist_knm_quadratic(mesh, kern, xs, z, n)
    v = jax.random.normal(jax.random.PRNGKey(1), (m,))
    g = kern.cross(x, z)
    want = g.T @ (g @ v)
    got = op(v)
    rel = float(jnp.linalg.norm(got - want) / jnp.linalg.norm(want))
    assert rel < 1e-4, rel

    # distributed matvec accepts multi-RHS panels (one Gram eval, k columns)
    vp = jax.random.normal(jax.random.PRNGKey(2), (m, 3))
    wantp = g.T @ (g @ vp)
    gotp = op(vp)
    rel = float(jnp.linalg.norm(gotp - wantp) / jnp.linalg.norm(wantp))
    assert gotp.shape == (m, 3) and rel < 1e-4, rel

    # distributed FALKON == local FALKON
    fd = falkon_fit_distributed(mesh, kern, x, y, z, 1e-3, iters=20)
    fl = falkon_fit(kern, x, y, z, 1e-3, iters=20)
    rel = float(jnp.linalg.norm(fd.alpha - fl.alpha) / jnp.linalg.norm(fl.alpha))
    assert rel < 1e-3, rel

    # distributed multi-RHS FALKON == local multi-RHS FALKON (8 devices)
    Y = jnp.stack([y, jnp.cos(x[:, 1]), 0.3 * x[:, 2] ** 2], axis=1)
    fdm = falkon_fit_distributed(mesh, kern, x, Y, z, 1e-3, iters=20)
    flm = falkon_fit(kern, x, Y, z, 1e-3, iters=20)
    rel = float(jnp.linalg.norm(fdm.alpha - flm.alpha) / jnp.linalg.norm(flm.alpha))
    assert fdm.alpha.shape == (m, 3) and rel < 1e-3, rel

    # collective parser sees the psum in the compiled distributed matvec
    from repro.launch.hlo_analysis import collective_bytes
    lowered = jax.jit(op).lower(v)
    txt = lowered.compile().as_text()
    coll = collective_bytes(txt)
    assert coll["all-reduce"] > 0, coll
    print("DISTRIBUTED_OK")
""")


@pytest.mark.slow
def test_distributed_matches_local_on_8_devices():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))
    out = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600)
    assert "DISTRIBUTED_OK" in out.stdout, out.stdout + out.stderr
