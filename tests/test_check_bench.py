"""Unit tests for the CI perf-regression gate (tools/check_bench.py)."""
from __future__ import annotations

import importlib.util
import json
import sys
from pathlib import Path

import pytest

_TOOLS = Path(__file__).resolve().parent.parent / "tools"
_spec = importlib.util.spec_from_file_location(
    "check_bench", _TOOLS / "check_bench.py")
check_bench = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(check_bench)

BASE = {"a": 900.0, "b": 48_000.0, "c": 190_000.0,
        "d": 80_000.0, "e": 120_000.0}


def test_clean_run_passes():
    cur = {k: v * 1.05 for k, v in BASE.items()}
    ok, lines = check_bench.compare(cur, BASE)
    assert ok, "\n".join(lines)


def test_single_row_regression_fails_row_gate():
    cur = dict(BASE)
    cur["c"] = BASE["c"] * 5
    ok, lines = check_bench.compare(cur, BASE)
    assert not ok
    assert any("FAIL: row c" in ln for ln in lines)


def test_uniform_slowdown_fails_median_gate():
    cur = {k: v * 3.0 for k, v in BASE.items()}
    ok, lines = check_bench.compare(cur, BASE)
    assert not ok
    assert any("FAIL: median ratio" in ln for ln in lines)


def test_slow_runner_spread_is_tolerated():
    """A uniformly 1.4x-slower runner is machine spread, not a regression."""
    cur = {k: v * 1.4 for k, v in BASE.items()}
    ok, lines = check_bench.compare(cur, BASE)
    assert ok, "\n".join(lines)


def test_jitter_floor_skips_tiny_rows():
    cur = dict(BASE)
    cur["a"] = BASE["a"] * 50  # 45ms — but 'a' is sub-floor on baseline? no:
    # both sides must be sub-floor to skip; 45ms current crosses the floor.
    ok, lines = check_bench.compare(cur, BASE)
    assert not ok
    cur["a"] = BASE["a"] * 3  # 2.7ms: both sides under the 5ms floor -> skip
    ok, lines = check_bench.compare(cur, BASE)
    assert ok, "\n".join(lines)
    assert any("skipped 1 sub-floor" in ln for ln in lines)


def test_disjoint_rows_fail():
    ok, lines = check_bench.compare({"x": 1.0}, BASE)
    assert not ok
    assert any("no shared rows" in ln for ln in lines)


def test_absolute_mode_gates_raw_ratios():
    cur = {k: v * 2.0 for k, v in BASE.items()}
    ok, _ = check_bench.compare(cur, BASE, mode="absolute", row_max=1.5,
                                median_max=10.0)
    assert not ok
    ok, _ = check_bench.compare(cur, BASE, mode="normalized", row_max=1.5,
                                median_max=10.0)
    assert ok


def test_find_baseline_picks_newest_pr(tmp_path):
    for name, rows in [("BENCH_PR4.json", [{"name": "a", "us_per_call": 1}]),
                       ("BENCH_PR6.json", [{"name": "a", "us_per_call": 2}]),
                       ("BENCH_PR6_SMOKE.json",
                        [{"name": "a", "us_per_call": 3}])]:
        (tmp_path / name).write_text(json.dumps(rows))
    assert check_bench.find_baseline(tmp_path).name == "BENCH_PR6.json"
    assert check_bench.find_baseline(
        tmp_path, smoke=True).name == "BENCH_PR6_SMOKE.json"
    with pytest.raises(FileNotFoundError):
        check_bench.find_baseline(tmp_path / "nowhere")


def test_cli_end_to_end(tmp_path, capsys):
    base = [{"name": k, "us_per_call": v, "derived": ""}
            for k, v in BASE.items()]
    cur = [{"name": k, "us_per_call": v * 1.1, "derived": ""}
           for k, v in BASE.items()]
    bpath, cpath = tmp_path / "base.json", tmp_path / "cur.json"
    bpath.write_text(json.dumps(base))
    cpath.write_text(json.dumps(cur))
    report = tmp_path / "diff.txt"
    rc = check_bench.main([str(cpath), "--baseline", str(bpath),
                           "--report", str(report)])
    assert rc == 0
    assert "OK: within regression bounds" in report.read_text()
    cur[2]["us_per_call"] = BASE["c"] * 9
    cpath.write_text(json.dumps(cur))
    rc = check_bench.main([str(cpath), "--baseline", str(bpath)])
    assert rc == 1


if __name__ == "__main__":
    sys.exit(pytest.main([__file__, "-q"]))
