"""repro.api front door: Sampler protocol, estimator contracts, bit-for-bit
parity with the legacy free functions, multi-output fits, warm-start refits
on the fused-fit cache, and the public-surface guard."""
import inspect

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.api as api
from repro.api import (BlessRSampler, BlessSampler, ExactKrr, ExactRlsSampler,
                       FalkonRegressor, FitConfig, KrrServer, NystromRegressor,
                       RecursiveRlsSampler, Sampler, SqueakSampler,
                       TwoPassSampler, UniformSampler, make_kernel)
from repro.core import falkon_bless_fit, falkon_fit, nystrom_krr
from repro.core import falkon as falkon_mod
from repro.core.leverage import CenterSet

KERN = make_kernel("gaussian", sigma=1.5)
BACKENDS = ["jnp", "pallas", "sharded"]

SAMPLERS = [
    BlessSampler(lam=1e-2, m_cap=128),
    BlessRSampler(lam=1e-2, m_cap=128),
    UniformSampler(m=48),
    ExactRlsSampler(m=48, lam=1e-2),
    RecursiveRlsSampler(lam=1e-2, m_cap=128),
    SqueakSampler(lam=1e-2, m_cap=128),
    TwoPassSampler(lam=1e-2, m2=48),
]


def _problem(n=400, d=6, seed=0):
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (n, d))
    y = jnp.sin(2 * x[:, 0]) + 0.3 * x[:, 1] ** 2
    return x, y


# -- Sampler protocol --------------------------------------------------------


@pytest.mark.parametrize("sampler", SAMPLERS, ids=lambda s: type(s).__name__)
def test_samplers_satisfy_protocol_and_centerset_invariants(sampler):
    assert isinstance(sampler, Sampler)  # runtime_checkable structural check
    x, _ = _problem()
    cs = sampler.sample(jax.random.PRNGKey(3), x, KERN, backend="jnp")
    assert isinstance(cs, CenterSet)
    m = int(cs.count)
    assert 0 < m <= cs.idx.shape[0]
    assert bool(jnp.all(cs.mask == (jnp.arange(cs.idx.shape[0]) < m)))
    assert bool(jnp.all((cs.idx >= 0) & (cs.idx < x.shape[0])))
    # invalid slots carry weight 1 (keeps padded K_JJ + lam n A conditioned)
    assert bool(jnp.all(jnp.where(cs.mask, True, cs.weight == 1.0)))
    assert bool(jnp.all(cs.weight[:m] > 0))


def test_samplers_are_hashable_and_comparable():
    assert BlessSampler() == BlessSampler()
    assert BlessSampler(lam=1e-2) != BlessSampler(lam=1e-3)
    {UniformSampler(m=8), ExactRlsSampler(m=8)}  # hashable


def test_uniform_sampler_weight_modes():
    x, _ = _problem(n=200)
    nys = UniformSampler(m=32).sample(jax.random.PRNGKey(0), x, KERN)
    ident = UniformSampler(m=32, weights="identity").sample(jax.random.PRNGKey(0), x, KERN)
    np.testing.assert_allclose(nys.weight[:32], 32 / 200)
    np.testing.assert_allclose(ident.weight[:32], 1.0)
    with pytest.raises(ValueError, match="weights"):
        UniformSampler(m=8, weights="typo").sample(jax.random.PRNGKey(0), x, KERN)


# -- estimator contracts -----------------------------------------------------


def test_falkon_regressor_fit_predict_score():
    x, y = _problem()
    est = FalkonRegressor(kernel=KERN, sampler=UniformSampler(m=96),
                          config=FitConfig(lam=1e-4, iters=30, backend="jnp"))
    assert est.fit(x, y) is est  # sklearn contract: fit returns self
    assert est.predict(x).shape == (x.shape[0],)
    assert est.score(x, y) > 0.6  # far better than predicting the mean
    assert est.centers_.shape == (96, x.shape[1])
    assert est.a_diag_.shape == (96,)


def test_unfitted_estimator_raises():
    est = FalkonRegressor(kernel=KERN)
    with pytest.raises(RuntimeError, match="not fitted"):
        est.predict(jnp.zeros((3, 6)))


def test_kernel_accepted_by_name():
    x, y = _problem(n=200)
    est = ExactKrr(kernel="matern32", sigma=2.0, config=FitConfig(lam=1e-3))
    assert est.kernel.name == "matern32" and est.kernel.sigma == 2.0
    assert est.fit(x, y).score(x, y) > 0.9


def test_nystrom_regressor_matches_core_solver():
    x, y = _problem()
    sampler = UniformSampler(m=64)
    est = NystromRegressor(kernel=KERN, sampler=sampler,
                           config=FitConfig(lam=1e-3, backend="jnp", seed=5))
    est.fit(x, y)
    cs = sampler.sample(jax.random.PRNGKey(5), x, KERN, backend="jnp")
    ref = nystrom_krr(KERN, x, y, x[cs.idx[: int(cs.count)]], 1e-3, backend="jnp")
    assert bool(jnp.array_equal(est.model_.alpha, ref.alpha))


def test_estimators_rank_as_expected():
    """Oracle >= direct Nystrom ~= FALKON on the same centers."""
    x, y = _problem()
    cfg = FitConfig(lam=1e-4, iters=40, backend="jnp", seed=1)
    sampler = UniformSampler(m=96)
    fk = FalkonRegressor(kernel=KERN, sampler=sampler, config=cfg).fit(x, y)
    ny = NystromRegressor(kernel=KERN, sampler=sampler, config=cfg).fit(x, y)
    ex = ExactKrr(kernel=KERN, config=cfg).fit(x, y)
    assert abs(fk.score(x, y) - ny.score(x, y)) < 1e-2  # CG converged to Def. 4
    assert ex.score(x, y) >= ny.score(x, y) - 1e-3


# -- parity with the legacy entry points (the acceptance bar) ----------------


@pytest.mark.parametrize("name", BACKENDS)
def test_falkon_regressor_reproduces_falkon_bless_fit_bitwise(name):
    x, y = _problem()
    key = jax.random.PRNGKey(11)
    est = FalkonRegressor(kernel=KERN,
                          sampler=BlessSampler(lam=1e-3, q2=3.0, m_cap=200),
                          config=FitConfig(lam=1e-5, iters=15, backend=name))
    est.fit(x, y, key=key)
    ref = falkon_bless_fit(key, KERN, x, y, 1e-3, 1e-5, iters=15, q2=3.0,
                           m_cap=200, backend=name)
    assert bool(jnp.array_equal(est.model_.centers, ref.centers))
    assert bool(jnp.array_equal(est.model_.alpha, ref.alpha))


def test_center_set_bypass_matches_sampler_path():
    x, y = _problem()
    sampler = BlessSampler(lam=1e-2, m_cap=128)
    cs = sampler.sample(jax.random.PRNGKey(0), x, KERN, backend="jnp")
    cfg = FitConfig(lam=1e-4, iters=15, backend="jnp", seed=0)
    via_sampler = FalkonRegressor(kernel=KERN, sampler=sampler, config=cfg).fit(x, y)
    via_cs = FalkonRegressor(kernel=KERN, config=cfg).fit(x, y, center_set=cs)
    assert bool(jnp.array_equal(via_sampler.model_.alpha, via_cs.model_.alpha))


# -- multi-output y ----------------------------------------------------------


@pytest.mark.parametrize("name", BACKENDS)
def test_multi_output_matches_columnwise_fits(name):
    """Multi-output rides ONE multi-RHS block-CG; each column must agree
    with an independent single-RHS fit to CG/fp32 tolerance (the solves
    share the matvec panel, so bitwise equality is not expected)."""
    x, y = _problem()
    Y = jnp.stack([y, jnp.cos(x[:, 2]), -0.5 * y + 1.0], axis=1)
    est = FalkonRegressor(kernel=KERN, sampler=UniformSampler(m=48),
                          config=FitConfig(lam=1e-3, iters=15, backend=name))
    est.fit(x, Y)
    assert est.model_.alpha.shape == (48, 3)
    pred = est.predict(x)
    assert pred.shape == (x.shape[0], 3)
    for j in range(3):
        col = falkon_fit(KERN, x, Y[:, j], est.centers_, 1e-3,
                         a_diag=est.a_diag_, iters=15, backend=name)
        # alpha itself is ill-conditioned (the CG solves reassociate), so
        # parity is norm-relative on alpha and tight on predictions
        rel_a = float(jnp.linalg.norm(est.model_.alpha[:, j] - col.alpha)
                      / jnp.linalg.norm(col.alpha))
        assert rel_a < 5e-3, (name, j, rel_a)
        ref = col.predict(x)
        rel_p = float(jnp.linalg.norm(pred[:, j] - ref) / jnp.linalg.norm(ref))
        assert rel_p < 1e-3, (name, j, rel_p)
    assert est.score(x, Y) > 0.5


def test_multi_output_exact_and_nystrom():
    x, y = _problem(n=250)
    Y = jnp.stack([y, -y], axis=1)
    ex = ExactKrr(kernel=KERN, config=FitConfig(lam=1e-3, backend="jnp")).fit(x, Y)
    ny = NystromRegressor(kernel=KERN, sampler=UniformSampler(m=64),
                          config=FitConfig(lam=1e-3, backend="jnp")).fit(x, Y)
    for est in (ex, ny):
        assert est.predict(x).shape == (250, 2)
    # symmetric targets -> symmetric predictions
    p = ex.predict(x)
    np.testing.assert_allclose(p[:, 0], -p[:, 1], rtol=1e-4, atol=1e-5)


def test_score_rejects_mismatched_target_shape():
    x, y = _problem(n=200)
    est = FalkonRegressor(kernel=KERN, sampler=UniformSampler(m=32),
                          config=FitConfig(lam=1e-3, iters=10, backend="jnp"))
    est.fit(x, y)  # single-output model
    with pytest.raises(ValueError, match="shape"):
        est.score(x, y[:, None])  # (n, 1) would silently broadcast to (n, n)


# -- warm-start refits on the fused-fit cache --------------------------------


def test_warm_start_refit_rides_fused_cache():
    x, y = _problem(n=500)
    est = FalkonRegressor(kernel=KERN, sampler=UniformSampler(m=56),
                          config=FitConfig(lam=1e-3, iters=17, backend="jnp"),
                          warm_start=True)
    est.fit(x, y)
    centers0 = est.centers_
    traces0 = falkon_mod._FUSED_FIT_TRACES
    # refit with new targets and a new lam: centers reused, zero retraces
    est.config = FitConfig(lam=1e-4, iters=17, backend="jnp")
    est.fit(x, jnp.cos(x[:, 0]))
    assert est.centers_ is centers0  # no re-sampling
    assert falkon_mod._FUSED_FIT_TRACES == traces0  # fused-fit cache hit
    # without warm_start the sampler runs again (same draw, new arrays)
    est.warm_start = False
    est.fit(x, y)
    assert est.centers_ is not centers0


def test_warm_start_resamples_on_different_data_shape():
    """Centers are rows of the previous X: a different row count must break
    the warm start even though the feature dim matches."""
    x, y = _problem(n=300)
    x2, y2 = _problem(n=260, seed=4)
    est = FalkonRegressor(kernel=KERN, sampler=UniformSampler(m=32),
                          config=FitConfig(lam=1e-3, iters=10, backend="jnp"),
                          warm_start=True)
    est.fit(x, y)
    centers0 = est.centers_
    est.fit(x2, y2)  # same d, different n -> re-sample from x2
    assert est.centers_ is not centers0
    assert bool(jnp.all(est.center_set_.idx[: int(est.center_set_.count)]
                        < x2.shape[0]))


# -- serving integration -----------------------------------------------------


def test_krr_server_accepts_fitted_estimator_and_multi_output():
    x, y = _problem()
    Y = jnp.stack([y, 2.0 * y], axis=1)
    est = FalkonRegressor(kernel=KERN, sampler=UniformSampler(m=48),
                          config=FitConfig(lam=1e-3, iters=15, backend="jnp"))
    server = KrrServer(est.fit(x, Y), max_wave=256)
    out = server.predict(x[:37])
    assert out.shape == (37, 2)
    np.testing.assert_allclose(out, est.predict(x[:37]), rtol=1e-6, atol=1e-6)


def test_krr_server_rejects_unfitted_estimator():
    with pytest.raises(ValueError, match="fit"):
        KrrServer(FalkonRegressor(kernel=KERN))


# -- API surface guard -------------------------------------------------------


def test_api_all_importable_and_public():
    assert len(api.__all__) == len(set(api.__all__))
    for name in api.__all__:
        assert not name.startswith("_"), name
        assert getattr(api, name) is not None


def test_api_surface_is_exactly_all():
    """No core internals leak through the front door: every public attribute
    of repro.api is either in __all__ or a submodule of the package."""
    public = {n for n in vars(api) if not n.startswith("_")}
    modules = {n for n in public if inspect.ismodule(getattr(api, n))}
    assert modules <= {"estimators", "samplers", "sweep"}, modules
    assert public - modules == set(api.__all__)


def test_api_does_not_leak_core_helpers():
    for leaked in ("local_knm_quadratic", "resolve_backend", "_chol_with_jitter",
                   "blocked_cross", "approx_rls"):
        assert not hasattr(api, leaked), leaked
