"""Out-of-core streaming subsystem (repro.stream) — DESIGN.md §10.

Parity is measured against JnpBackend (the numerical reference) at the
documented scale-relative 1e-4 for single contractions; end-to-end FALKON
through the stream backend is held to the CG-reassociation class (rel 1e-3)
because the chunk accumulation order differs from the jnp streamer's scan.
The peak-memory tests are the subsystem's core claim: no (n, M) array.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (JnpBackend, default_backend, falkon_fit, make_kernel,
                        resolve_backend)
from repro.core.bless import bless
from repro.core.leverage import approx_rls_all, uniform_center_set
from repro.stream import (ChunkStore, StreamBackend, device_chunks,
                          peak_device_bytes, reset_peak_device_bytes)

JNP = JnpBackend()
KERN = make_kernel("gaussian", sigma=1.5)


def _close(a, b, tol=1e-4):
    a, b = np.asarray(a), np.asarray(b)
    scale = max(1.0, float(np.max(np.abs(a))))
    np.testing.assert_allclose(a, b, rtol=tol, atol=tol * scale)


def _xy(n, d=5, k=None, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((n, d), dtype=np.float32)
    shape = (n,) if k is None else (n, k)
    y = rng.standard_normal(shape).astype(np.float32)
    return x, y


# ---------------------------------------------------------------------------
# ChunkStore data plane
# ---------------------------------------------------------------------------


def test_chunkstore_surface():
    x, y = _xy(103, d=4)
    store = ChunkStore(x, y, chunk=40)
    assert store.shape == (103, 4) and store.ndim == 2 and len(store) == 103
    assert store.n_chunks == 3  # 40 + 40 + 23: tail carries the remainder
    sl = store.chunk_slices()
    assert sl[0] == slice(0, 40) and sl[-1] == slice(80, 103)
    np.testing.assert_array_equal(np.asarray(store[5]), x[5])
    np.testing.assert_array_equal(np.asarray(store[10:20]), x[10:20])
    idx = jnp.asarray([7, 3, 99])
    np.testing.assert_array_equal(np.asarray(store[idx]), x[[7, 3, 99]])
    np.testing.assert_array_equal(np.asarray(jnp.asarray(store)), x)  # O(n d) hatch


def test_chunkstore_rejects_traced_gather():
    store = ChunkStore(_xy(32)[0])
    with pytest.raises(TypeError, match="concrete"):
        jax.jit(lambda i: store[i])(jnp.asarray([0, 1]))


def test_chunkstore_validates():
    with pytest.raises(ValueError, match=r"\(n, d\)"):
        ChunkStore(np.zeros((4,), np.float32))
    with pytest.raises(ValueError, match="rows"):
        ChunkStore(np.zeros((4, 2), np.float32), np.zeros((5,), np.float32))


def test_device_chunks_cover_exactly():
    x, y = _xy(97, d=3)
    store = ChunkStore(x, chunk=16)
    xs, ys = [], []
    for xb, yb in device_chunks(store, aux=y):
        xs.append(np.asarray(xb))
        ys.append(np.asarray(yb))
    np.testing.assert_array_equal(np.concatenate(xs), x)
    np.testing.assert_array_equal(np.concatenate(ys), y)


# ---------------------------------------------------------------------------
# Tile-size sweep: non-divisible n, chunk=1, chunk > n
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("chunk", [1, 16, 37, 137, 500])
def test_chunk_size_sweep(chunk):
    n, m = 137, 12  # prime n: never divisible by the sweep's chunks > 1
    x, y = _xy(n)
    z = jnp.asarray(x[:m])
    xd = jnp.asarray(x)
    store = ChunkStore(x, chunk=chunk)
    sb = StreamBackend()
    v = jnp.linspace(-1.0, 1.0, m)
    _close(sb.knm_matvec(KERN, store, z, v), JNP.knm_matvec(KERN, xd, z, v))
    _close(sb.knm_t(KERN, store, z, jnp.asarray(y)),
           JNP.knm_t(KERN, xd, z, jnp.asarray(y)))
    _close(sb.knm_quadratic(KERN, store, z)(v),
           JNP.knm_quadratic(KERN, xd, z)(v))
    _close(sb.gram_block(KERN, store, z), JNP.gram_block(KERN, xd, z))


def test_backend_chunk_override_beats_store_chunk():
    x, _ = _xy(64)
    store = ChunkStore(x, chunk=8)
    sb = StreamBackend(chunk=50)  # backend chunk wins over the store's
    z = jnp.asarray(x[:6])
    reset_peak_device_bytes()
    sb.knm_matvec(KERN, store, z, jnp.ones((6,)))
    # two 50-row (tail 14) chunks resident at once, plus their tiles
    assert peak_device_bytes() <= 4 * (2 * 50 * x.shape[1] + 50 * 6) + 256


# ---------------------------------------------------------------------------
# Kernel families x multi-RHS panels
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("family", ["gaussian", "laplacian", "linear",
                                    "matern32", "cauchy"])
@pytest.mark.parametrize("k", [1, 3, 8])
def test_family_multirhs_parity(family, k):
    kern = make_kernel(family, sigma=1.8)
    n, m, d = 193, 14, 4
    x, ym = _xy(n, d=d, k=k, seed=3)
    y = ym[:, 0] if k == 1 else ym  # k=1 exercises the 1-D contract
    z = jnp.asarray(x[:m])
    xd = jnp.asarray(x)
    store = ChunkStore(x, chunk=48)
    sb = StreamBackend()
    rng = np.random.default_rng(4)
    v = jnp.asarray(rng.standard_normal((m,) if k == 1 else (m, k)).astype(np.float32))
    out = sb.knm_matvec(kern, store, z, v)
    assert out.shape == ((n,) if k == 1 else (n, k))
    _close(out, JNP.knm_matvec(kern, xd, z, v))
    kty = sb.knm_t(kern, store, z, jnp.asarray(y))
    assert kty.shape == ((m,) if k == 1 else (m, k))
    _close(kty, JNP.knm_t(kern, xd, z, jnp.asarray(y)))
    _close(sb.knm_quadratic(kern, store, z)(v),
           JNP.knm_quadratic(kern, xd, z)(v))


def test_quadform_and_rls_parity():
    n, mbuf = 211, 16
    x, _ = _xy(n, seed=5)
    xd = jnp.asarray(x)
    store = ChunkStore(x, chunk=64)
    sb = StreamBackend()
    cs = uniform_center_set(jnp.arange(12), n, mbuf)
    z = xd[cs.idx]
    lamn = jnp.asarray(1e-2 * n, jnp.float32)
    reg = jnp.where(cs.mask, lamn * cs.weight, 1.0)
    _close(sb.masked_quadform(KERN, store, z, cs.mask, reg),
           JNP.masked_quadform(KERN, xd, z, cs.mask, reg))
    _close(sb.rls_scores(KERN, store, z, cs.mask, reg, lamn),
           JNP.rls_scores(KERN, xd, z, cs.mask, reg, lamn))


# ---------------------------------------------------------------------------
# End-to-end: falkon_fit / predict / BLESS on a host-resident store
# ---------------------------------------------------------------------------


def test_falkon_fit_predict_parity():
    n, m, k = 1200, 48, 3
    x, ym = _xy(n, d=6, k=k, seed=7)
    z = jnp.asarray(x[:m])
    store = ChunkStore(x, chunk=256)
    lam = 1e-4
    ref = falkon_fit(KERN, jnp.asarray(x), jnp.asarray(ym), z, lam, iters=12,
                     backend=JNP, fused=False)
    mod = falkon_fit(KERN, store, jnp.asarray(ym), z, lam, iters=12,
                     backend=StreamBackend())
    xq = jnp.asarray(x[:200])
    p_ref, p_str = ref.predict(xq, backend=JNP), mod.predict(xq)
    # chunk-order accumulation reassociates the CG sums: rel 1e-3 class
    rel = float(jnp.max(jnp.abs(p_ref - p_str)) / jnp.max(jnp.abs(p_ref)))
    assert rel < 1e-3
    # predict straight off the store as well (the serving path at big n)
    p_store = mod.predict(store)
    assert p_store.shape == (n, k)
    rel = float(jnp.max(jnp.abs(p_store[:200] - p_ref)) / jnp.max(jnp.abs(p_ref)))
    assert rel < 1e-3


def test_bless_on_store_matches_jnp_scale():
    n = 900
    x, _ = _xy(n, d=4, seed=11)
    key = jax.random.PRNGKey(2)
    lam = 2e-3
    res_ref = bless(key, jnp.asarray(x), KERN, lam, backend=JNP, m_cap=300)
    res_str = bless(key, ChunkStore(x, chunk=200), KERN, lam,
                    backend=StreamBackend(), m_cap=300)
    assert len(res_str.levels) == len(res_ref.levels)
    m_ref, m_str = res_ref.final.m_h, res_str.final.m_h
    # same draws up to fp reassociation in the scores: sizes agree closely
    assert 0.5 * m_ref <= m_str <= 2.0 * m_ref
    # the sampled set must score equivalently through both paths
    s_ref = approx_rls_all(KERN, jnp.asarray(x), res_str.final.centers,
                           jnp.asarray(lam), backend=JNP)
    s_str = approx_rls_all(KERN, ChunkStore(x, chunk=200),
                           res_str.final.centers, jnp.asarray(lam),
                           backend=StreamBackend())
    _close(s_str, s_ref, tol=2e-4)


# ---------------------------------------------------------------------------
# The memory claim: no (n, M) materialization
# ---------------------------------------------------------------------------


def test_peak_memory_stays_far_below_knm():
    n, m, d, chunk = 60_000, 64, 8, 4096
    x, y = _xy(n, d=d, seed=13)
    store = ChunkStore(x, y, chunk=chunk)
    z = store[np.arange(m)]
    sb = StreamBackend()
    reset_peak_device_bytes()
    op = sb.knm_quadratic(KERN, store, z)
    v = jnp.ones((m,), jnp.float32)
    jax.block_until_ready(op(v))
    jax.block_until_ready(sb.knm_t(KERN, store, z, jnp.asarray(y)))
    peak = peak_device_bytes()
    knm_bytes = 4 * n * m  # what a materialized K_nM would cost
    working_set = 4 * (2 * chunk * d + chunk * m)  # 2 chunks + 1 tile
    assert peak <= working_set + 4 * 2 * chunk  # slack: y chunks
    assert peak < knm_bytes / 10
    # and the bound is n-independent: double n, same working set
    x2, y2 = _xy(2 * n, d=d, seed=14)
    reset_peak_device_bytes()
    jax.block_until_ready(
        sb.knm_quadratic(KERN, ChunkStore(x2, chunk=chunk), z)(v))
    assert peak_device_bytes() <= working_set + 4 * 2 * chunk


def test_compiled_chunk_step_memory_is_n_independent():
    """Cost-analysis proof: the compiled per-chunk program's temp footprint
    depends on (chunk, M), never on n — streaming 10x the rows reuses the
    same executable with the same temporary allocations."""
    from repro.stream.backend import _quad_chunk

    m, d, chunk = 32, 6, 512
    z = jnp.zeros((m, d), jnp.float32)
    v = jnp.zeros((m,), jnp.float32)
    acc = jnp.zeros((m,), jnp.float32)
    xb = jnp.zeros((chunk, d), jnp.float32)
    step = jax.jit(lambda *a: _quad_chunk(KERN, *a, inner=JNP))
    compiled = step.lower(xb, z, v, acc).compile()
    analysis = compiled.memory_analysis()
    if analysis is None:  # platform without memory analysis
        pytest.skip("memory_analysis unavailable")
    temp = int(analysis.temp_size_in_bytes)
    # the footprint is a few (chunk, m) tiles — nothing anywhere near (n, m)
    assert temp <= 4 * chunk * m * 8


def test_gram_block_materialization_guard():
    x, _ = _xy(4096, d=3)
    store = ChunkStore(x, chunk=1024)
    z = store[np.arange(8)]
    sb = StreamBackend(materialize_elems=4096 * 8 - 1)
    with pytest.raises(ValueError, match="refuses to materialize"):
        sb.gram_block(KERN, store, z)
    # raising the guard (small problems) streams and concatenates fine
    ok = StreamBackend().gram_block(KERN, store, z)
    assert ok.shape == (4096, 8)


# ---------------------------------------------------------------------------
# Registry / composition / selection
# ---------------------------------------------------------------------------


def test_registry_and_composition():
    assert isinstance(resolve_backend("stream"), StreamBackend)
    comp = resolve_backend("stream:pallas")
    assert isinstance(comp, StreamBackend)
    assert comp.inner.name == "pallas"
    with pytest.raises(ValueError, match="unknown backend"):
        resolve_backend("stream:cuda")
    with pytest.raises(ValueError, match="not composable"):
        resolve_backend("jnp:pallas")


def test_env_stream_spec(monkeypatch):
    monkeypatch.setenv("REPRO_BACKEND", "stream")
    assert isinstance(default_backend(), StreamBackend)
    monkeypatch.setenv("REPRO_BACKEND", "stream:jnp")
    be = default_backend()
    assert isinstance(be, StreamBackend) and isinstance(be.inner, JnpBackend)
    monkeypatch.setenv("REPRO_BACKEND", "stream:cuda")
    with pytest.raises(ValueError, match="REPRO_BACKEND"):
        default_backend()


def test_stream_threshold_env_override(monkeypatch):
    monkeypatch.setenv("REPRO_STREAM_MIN_ROWS", "1000")
    be = default_backend(2000)
    assert isinstance(be, StreamBackend)
    assert isinstance(be.inner, JnpBackend)  # wraps the heuristic's pick
    monkeypatch.setenv("REPRO_STREAM_MIN_ROWS", "100000")
    assert isinstance(default_backend(2000), JnpBackend)


def test_with_inner_is_pure():
    base = StreamBackend(chunk=1000)
    swapped = base.with_inner(JnpBackend(block=64))
    assert swapped.chunk == 1000 and swapped.inner == JnpBackend(block=64)
    assert base.inner == JnpBackend()  # frozen: original untouched
    assert dataclasses.asdict(base)  # still a plain frozen dataclass


# ---------------------------------------------------------------------------
# PR 9 scenarios at out-of-core n: masked ops, classifier, variance
# ---------------------------------------------------------------------------


def test_masked_quadratic_streams_mask_as_aux():
    """The (n, k) mask panel rides the chunk iterator next to X — masked
    ops agree with the jnp seam and never put the whole mask on device."""
    n, m, k, chunk = 40_000, 32, 4, 2048
    x, _ = _xy(n, d=5, seed=19)
    rng = np.random.default_rng(20)
    mask = (rng.uniform(size=(n, k)) > 0.25).astype(np.float32)
    z = jnp.asarray(x[:m])
    v = jnp.asarray(rng.standard_normal((m, k)).astype(np.float32))
    store = ChunkStore(x, chunk=chunk)
    sb = StreamBackend()
    reset_peak_device_bytes()
    out = sb.knm_quadratic(KERN, store, z, mask=mask)(v)
    peak = peak_device_bytes()
    _close(out, JNP.knm_quadratic(KERN, jnp.asarray(x), z,
                                  mask=jnp.asarray(mask))(v))
    # working set: 2 x-chunks + 2 mask-chunks + one (chunk, m) tile — the
    # full (n, k) mask (640 KB) must never be device-resident at once
    working_set = 4 * (2 * chunk * 5 + 2 * chunk * k + chunk * m)
    assert peak <= working_set + 4096
    assert peak < 4 * n * k  # < one full mask panel


def test_classifier_end_to_end_out_of_core():
    """FalkonClassifier on a host-resident ChunkStore: the panel fit, the
    margin predict, and the argmax labels all stream — peak device bytes
    stay in the working-set class, far below any (n, M) array."""
    from repro.api import FalkonClassifier, FitConfig, UniformSampler

    n, d, chunk = 30_000, 5, 2048
    rng = np.random.default_rng(23)
    labels = np.arange(n) % 3
    means = rng.standard_normal((3, d)).astype(np.float32) * 3.0
    x = means[labels] + rng.standard_normal((n, d)).astype(np.float32)
    store = ChunkStore(x, chunk=chunk)
    clf = FalkonClassifier(
        kernel=KERN, sampler=UniformSampler(m=64),
        config=FitConfig(lam=1e-4, iters=8, backend=StreamBackend()))
    reset_peak_device_bytes()
    clf.fit(store, labels)
    pred = clf.predict(store)
    peak = peak_device_bytes()
    assert pred.shape == (n,)
    acc = float(np.mean(pred == labels))
    assert acc > 0.9, acc
    # the fit + predict never materialize K_nM (4 n M = 7.7 MB here); the
    # O(n) device arrays are the (n, 3) margin panel and the fit targets
    assert peak < 4 * n * 64 / 2
    proba = clf.predict_proba(store)
    assert proba.shape == (n, 3)
    np.testing.assert_allclose(np.asarray(jnp.sum(proba, axis=1)), 1.0,
                               rtol=1e-5)


def test_predictive_variance_out_of_core():
    """predictive_variance on a ChunkStore streams the fused RLS scorer:
    parity with the jnp seam, working-set peak memory."""
    from repro.api import FalkonRegressor, FitConfig, UniformSampler

    n, d, chunk = 30_000, 5, 2048
    x, y = _xy(n, d=d, seed=29)
    store = ChunkStore(x, chunk=chunk)
    est = FalkonRegressor(
        kernel=KERN, sampler=UniformSampler(m=64),
        config=FitConfig(lam=1e-4, iters=8, backend=StreamBackend()))
    est.fit(store, jnp.asarray(y))
    reset_peak_device_bytes()
    var = est.predictive_variance(store)
    peak = peak_device_bytes()
    assert var.shape == (n,) and bool(jnp.all(var >= 0.0))
    ref = est.model_.predictive_variance(jnp.asarray(x[:512]), backend="jnp")
    _close(var[:512], ref, tol=1e-4)
    # the scorer holds 2 x-chunks + one (chunk, M) tile + the (n,) output
    working_set = 4 * (2 * chunk * d + chunk * 64 + n)
    assert peak <= working_set + 4096
    # return_std composes on the store too
    pred, std = est.predict(store, return_std=True)
    assert pred.shape == (n,) and std.shape == (n,)
    _close(std, jnp.sqrt(var), tol=1e-6)


def test_estimator_front_door_accepts_store():
    from repro.api import ChunkStore as ApiChunkStore
    from repro.api import FalkonRegressor, FitConfig, UniformSampler

    assert ApiChunkStore is ChunkStore
    n = 600
    x, y = _xy(n, d=4, seed=17)
    est = FalkonRegressor(
        kernel=KERN, sampler=UniformSampler(m=32),
        config=FitConfig(lam=1e-4, iters=8, backend=StreamBackend()))
    est.fit(ChunkStore(x, chunk=128), jnp.asarray(y[:, 0] if y.ndim == 2 else y))
    pred = est.predict(ChunkStore(x, chunk=128))
    assert pred.shape == (n,)
    ref = est.predict(jnp.asarray(x))
    _close(pred, ref, tol=1e-4)
