"""Shared fixtures. NOTE: no XLA_FLAGS here on purpose — smoke tests and
benches must see 1 device; multi-device tests spawn subprocesses."""
import jax
import jax.numpy as jnp
import pytest

try:
    import hypothesis  # noqa: F401
except ImportError:  # offline container: deterministic stub (CI has the real one)
    import _hypothesis_stub

    _hypothesis_stub.install()


@pytest.fixture(autouse=True, scope="module")
def _bound_compiled_program_accumulation():
    """Drop jit caches after every test module.

    The XLA:CPU JIT segfaults (in ``backend_compile``, compiling one of the
    sharded CG programs) once enough compiled executables have accumulated
    in a single process — the full suite crashed reproducibly around the
    ~250-program mark while every module passes in isolation and no
    half-suite subset reproduces it. Releasing compiled programs at module
    boundaries keeps the process under the cliff; within-module caching
    (what the retrace-guard tests pin) is untouched.
    """
    yield
    jax.clear_caches()


@pytest.fixture(scope="session")
def clustered_data():
    key = jax.random.PRNGKey(0)
    kc, ka, kn = jax.random.split(key, 3)
    n, d = 900, 6
    centers = jax.random.normal(kc, (8, d)) * 3.0
    assign = jax.random.randint(ka, (n,), 0, 8)
    x = centers[assign] + 0.5 * jax.random.normal(kn, (n, d))
    return x
