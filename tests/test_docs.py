"""Docs site integrity: every markdown link resolves (tools/check_docs_links)
and every public symbol of the documented surface (repro.api, repro.families,
repro.core.backend) carries a docstring — the local mirror of the CI
docs-check job's ruff pydocstyle D1xx gate, so a missing docstring fails
tier-1 before it fails CI lint."""
import inspect
import pathlib
import sys

import pytest

REPO = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO / "tools"))

import check_docs_links  # noqa: E402  (tools/ is not a package)

DOC_FILES = sorted(str(p.relative_to(REPO)) for p in (REPO / "docs").glob("*.md"))


def test_docs_guides_exist():
    assert {"docs/api.md", "docs/backends.md", "docs/benchmarks.md"} <= set(DOC_FILES)


@pytest.mark.parametrize("name", DOC_FILES + ["README.md", "DESIGN.md"])
def test_markdown_links_resolve(name):
    errors = check_docs_links.check_file(REPO / name)
    assert not errors, "\n".join(errors)


def test_readme_and_design_link_the_guides():
    readme = (REPO / "README.md").read_text()
    design = (REPO / "DESIGN.md").read_text()
    for guide in ("docs/api.md", "docs/backends.md", "docs/benchmarks.md"):
        assert guide in readme, f"README.md must link {guide}"
        assert guide in design, f"DESIGN.md must link {guide}"


def test_link_checker_catches_breakage(tmp_path):
    bad = tmp_path / "bad.md"
    bad.write_text("see [missing](no_such_file.md) and "
                   "[anchor](bad2.md#nope)\n")
    (tmp_path / "bad2.md").write_text("# Real heading\n")
    errors = check_docs_links.check_file(bad)
    assert len(errors) == 2
    good = tmp_path / "good.md"
    good.write_text("[ok](bad2.md#real-heading) and [web](https://x.invalid)\n")
    assert check_docs_links.check_file(good) == []


# -- docstring coverage (mirror of the ruff D1xx selection in pyproject) -----


def _public_members(obj):
    for name, member in vars(obj).items():
        if not name.startswith("_"):
            yield name, member


def _assert_documented(qualname, obj):
    assert (getattr(obj, "__doc__", None) or "").strip(), f"{qualname} lacks a docstring"


@pytest.mark.parametrize("modname", ["repro.api", "repro.api.samplers",
                                     "repro.api.estimators", "repro.api.sweep",
                                     "repro.families", "repro.core.backend"])
def test_documented_surface_has_docstrings(modname):
    """Every public class/function — and every public method of a public
    class — in the documented modules has a docstring (ruff D100-D103)."""
    import importlib

    mod = importlib.import_module(modname)
    _assert_documented(modname, mod)
    for name, member in _public_members(mod):
        if getattr(member, "__module__", None) != modname:
            continue  # re-exports are checked in their home module
        if inspect.isclass(member):
            _assert_documented(f"{modname}.{name}", member)
            for mname, meth in _public_members(member):
                if callable(meth) or isinstance(meth, property):
                    target = meth.fget if isinstance(meth, property) else meth
                    _assert_documented(f"{modname}.{name}.{mname}", target)
        elif inspect.isfunction(member):
            _assert_documented(f"{modname}.{name}", member)


def test_api_all_symbols_have_docstrings():
    """The acceptance bar: every repro.api public symbol is documented."""
    import repro.api as api

    for name in api.__all__:
        _assert_documented(f"repro.api.{name}", getattr(api, name))
