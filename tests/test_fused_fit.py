"""Fused whole-fit path (core/falkon.py, DESIGN.md §2.4): one compiled call
per shape bucket, no host-side CG dispatches on repeat fits, numerical
parity with the host-driven path."""
import jax
import jax.numpy as jnp
import pytest

from repro.core import PallasBackend, falkon_fit, make_kernel, nystrom_krr
from repro.core import falkon as falkon_mod

KERN = make_kernel("gaussian", sigma=1.5)


def _problem(n=500, m=64, seed=0):
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (n, 6))
    y = jnp.sin(2 * x[:, 0]) + 0.3 * x[:, 1] ** 2
    return x, y, x[:m]


def test_fused_fit_compiles_once_per_bucket():
    """Second fit in the same shape bucket is a single cached compiled call:
    zero retraces, hence zero host-side CG iteration dispatches.

    m=48 / iters=19 are unique to this test so fits compiled by other test
    files (the jit cache is process-wide) cannot mask the first trace.
    """
    x, y, z = _problem(m=48)
    t0 = falkon_mod._FUSED_FIT_TRACES
    m1 = falkon_fit(KERN, x, y, z, 1e-3, iters=19, backend="jnp")
    traces_after_first = falkon_mod._FUSED_FIT_TRACES
    assert traces_after_first == t0 + 1  # first call compiled the bucket
    # same shapes -> cache hit
    falkon_fit(KERN, x, y, z, 1e-3, iters=19, backend="jnp")
    # different n in the same row bucket -> still a cache hit
    falkon_fit(KERN, x[:400], y[:400], z, 1e-3, iters=19, backend="jnp")
    # lam and the kernel bandwidth are traced -> still a cache hit
    falkon_fit(KERN, x, y, z, 1e-4, iters=19, backend="jnp")
    falkon_fit(make_kernel("gaussian", sigma=2.5), x, y, z, 1e-3, iters=19,
               backend="jnp")
    assert falkon_mod._FUSED_FIT_TRACES == traces_after_first
    # different iters is a static key -> recompiles (sanity that the counter
    # actually observes tracing)
    falkon_fit(KERN, x, y, z, 1e-3, iters=18, backend="jnp")
    assert falkon_mod._FUSED_FIT_TRACES == traces_after_first + 1
    assert m1.alpha.shape == (z.shape[0],)


def test_fused_matches_host_path():
    x, y, z = _problem()
    fused = falkon_fit(KERN, x, y, z, 1e-3, iters=25, backend="jnp")
    host = falkon_fit(KERN, x, y, z, 1e-3, iters=25, backend="jnp", fused=False)
    pf, ph = fused.predict(x), host.predict(x)
    assert float(jnp.linalg.norm(pf - ph) / jnp.linalg.norm(ph)) < 1e-3


def test_fused_matches_nystrom_solution():
    """The compiled solve still converges to the Def. 4 direct solution."""
    x, y, z = _problem(n=400)
    fk = falkon_fit(KERN, x, y, z, 1e-3, iters=40, backend="jnp")
    ny = nystrom_krr(KERN, x, y, z, 1e-3)
    pf, pn = fk.predict(x), ny.predict(x)
    assert float(jnp.linalg.norm(pf - pn) / jnp.linalg.norm(pn)) < 1e-3


def test_fused_respects_weighted_preconditioner():
    x, y, z = _problem(n=300, m=32)
    a = jax.random.uniform(jax.random.PRNGKey(3), (32,), minval=0.5, maxval=2.0)
    fused = falkon_fit(KERN, x, y, z, 1e-3, a_diag=a, iters=25, backend="jnp")
    host = falkon_fit(KERN, x, y, z, 1e-3, a_diag=a, iters=25, backend="jnp",
                      fused=False)
    assert float(jnp.linalg.norm(fused.alpha - host.alpha)
                 / jnp.linalg.norm(host.alpha)) < 1e-3


def test_fused_flag_validation():
    x, y, z = _problem(n=200, m=16)
    with pytest.raises(ValueError, match="jit-safe"):
        falkon_fit(KERN, x, y, z, 1e-3, backend=PallasBackend(interpret=True),
                   fused=True)
    with pytest.raises(ValueError, match="callback"):
        falkon_fit(KERN, x, y, z, 1e-3, backend="jnp", fused=True,
                   callback=lambda i, m: None)
    # callback quietly takes the host path when fused is unset
    seen = []
    falkon_fit(KERN, x, y, z, 1e-3, iters=3, backend="jnp",
               callback=lambda i, m: seen.append(i))
    assert seen == [0, 1, 2]
