"""Attention variants: chunked==exact, decode==last-row, BLESS-Nystrom
approximation behaviour, leverage-score KV compression."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import (attention, bless_compress_cache,
                                    bless_topm_landmarks, decode_attention,
                                    nystrom_attention, rls_scores_one_rung)


def _qkv(s=96, hq=4, hkv=2, d=32, b=2, seed=0, scale=1.0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (b, s, hq, d)) * scale
    k = jax.random.normal(ks[1], (b, s, hkv, d)) * scale
    v = jax.random.normal(ks[2], (b, s, hkv, d))
    return q, k, v


def _exact(q, k, v, causal):
    b, s, hq, d = q.shape
    g = hq // k.shape[2]
    kf = jnp.repeat(k, g, axis=2).transpose(0, 2, 1, 3)
    vf = jnp.repeat(v, g, axis=2).transpose(0, 2, 1, 3)
    sc = jnp.einsum("bhqd,bhkd->bhqk", q.transpose(0, 2, 1, 3), kf) / math.sqrt(d)
    if causal:
        sc = jnp.where(jnp.tril(jnp.ones((s, s), bool)), sc, -jnp.inf)
    return jnp.einsum("bhqk,bhkd->bhqd", jax.nn.softmax(sc, -1), vf).transpose(0, 2, 1, 3)


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("chunk", [16, 33, 96, 512])
def test_chunked_attention_exact(causal, chunk):
    q, k, v = _qkv()
    out = attention(q, k, v, causal=causal, chunk=chunk)
    np.testing.assert_allclose(out, _exact(q, k, v, causal), rtol=2e-4, atol=2e-4)


def test_decode_attention_is_last_row():
    q, k, v = _qkv(s=40)
    full = _exact(q, k, v, causal=True)
    out = decode_attention(q[:, -1:], k, v, length=jnp.asarray(40))
    np.testing.assert_allclose(out[:, 0], full[:, -1], rtol=2e-4, atol=2e-4)


def test_decode_attention_per_slot_lengths():
    q, k, v = _qkv(s=40, b=2)
    lens = jnp.asarray([10, 40])
    out = decode_attention(q[:, -1:], k, v, length=lens)
    short = decode_attention(q[:1, -1:], k[:1, :10], v[:1, :10])
    np.testing.assert_allclose(out[0], short[0], rtol=2e-4, atol=2e-4)


def test_rls_scores_valid():
    keys = jax.random.normal(jax.random.PRNGKey(0), (128, 16))
    s = rls_scores_one_rung(keys, m_pilot=32, lam=1e-3)
    assert s.shape == (128,)
    assert float(s.min()) > 0 and float(s.max()) <= 1.0


def test_nystrom_error_decreases_with_landmarks():
    q, k, v = _qkv(s=256, scale=0.5)
    exact = attention(q, k, v, causal=False)
    errs = []
    for m in (16, 64, 192):
        approx = nystrom_attention(q, k, v, landmarks=m)
        errs.append(float(jnp.linalg.norm(approx - exact) / jnp.linalg.norm(exact)))
    assert errs[2] < errs[0]
    assert errs[2] < 0.2


def test_nystrom_beats_uniform_landmarks_on_skewed_keys():
    """The paper's point: leverage-score landmarks capture rare-but-
    important directions that uniform sampling misses."""
    key = jax.random.PRNGKey(0)
    s, d = 256, 16
    # 95% of keys in a tight cluster, 5% outliers carrying distinct values
    base = jax.random.normal(key, (s, d)) * 0.05
    out_idx = jnp.arange(0, s, 20)
    outliers = jax.random.normal(jax.random.PRNGKey(1), (out_idx.shape[0], d)) * 2.0
    kk = base.at[out_idx].set(outliers)
    scores = rls_scores_one_rung(kk, m_pilot=64, lam=1e-3)
    top = bless_topm_landmarks(kk, 16, m_pilot=64, lam=1e-3)
    hit = jnp.isin(top, out_idx).mean()
    assert float(hit) > 0.4  # outliers are high-leverage and get picked
    assert float(scores[out_idx].mean()) > 2.0 * float(scores.mean())


def test_bless_compress_cache_shapes_and_selection():
    b, s, h, d = 2, 128, 2, 16
    k = jax.random.normal(jax.random.PRNGKey(0), (b, s, h, d)) * 0.05
    v = jax.random.normal(jax.random.PRNGKey(1), (b, s, h, d))
    k = k.at[:, 7].set(5.0)  # one very distinctive key
    kc, vc = bless_compress_cache(k, v, m=16, m_pilot=32)
    assert kc.shape == (b, 16, h, d) and vc.shape == (b, 16, h, d)
    # the distinctive key must survive compression
    assert float(jnp.abs(kc).max()) >= 4.9
