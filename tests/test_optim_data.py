"""Optimizer, schedules, data pipeline determinism."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.data import SyntheticLM, TokenPipeline
from repro.optim import OptConfig, adamw_init, adamw_update
from repro.optim.schedules import cosine_schedule, wsd_schedule


def test_adamw_matches_scalar_reference():
    cfg = OptConfig(peak_lr=1e-2, warmup=0, total_steps=100, schedule="cosine",
                    weight_decay=0.0, clip_norm=1e9)
    params = {"w": jnp.asarray([1.0], jnp.float32)}
    state = adamw_init(params)
    g = jnp.asarray([0.5], jnp.float32)
    params, state = adamw_update(params, {"w": g}, state, cfg)
    # step 1: mu_hat = g, nu_hat = g^2 -> update = lr * g/|g| = lr
    lr1 = float(cfg.lr(1))
    np.testing.assert_allclose(float(params["w"][0]), 1.0 - lr1 * (0.5 / (0.5 + 1e-8)),
                               rtol=1e-5)


def test_grad_clip_applies():
    cfg = OptConfig(peak_lr=1e-2, warmup=0, clip_norm=1.0, weight_decay=0.0)
    params = {"w": jnp.zeros((4,), jnp.float32)}
    s1 = adamw_init(params)
    p1, _ = adamw_update(params, {"w": jnp.full((4,), 100.0)}, s1, cfg)
    s2 = adamw_init(params)
    p2, _ = adamw_update(params, {"w": jnp.full((4,), 1000.0)}, s2, cfg)
    np.testing.assert_allclose(p1["w"], p2["w"], rtol=1e-5)  # both clipped


@settings(max_examples=20, deadline=None)
@given(step=st.integers(0, 10_000))
def test_schedules_bounded_positive(step):
    for fn in (cosine_schedule, wsd_schedule):
        lr = float(fn(step, peak_lr=3e-4, warmup=100, total=10_000))
        assert 0.0 <= lr <= 3e-4 + 1e-9


def test_wsd_shape():
    kw = dict(peak_lr=1.0, warmup=10, total=100, decay_frac=0.2)
    assert float(wsd_schedule(5, **kw)) < 1.0  # warming
    assert float(wsd_schedule(50, **kw)) == 1.0  # stable
    assert float(wsd_schedule(99, **kw)) < 0.3  # decaying


def test_pipeline_determinism_and_resume():
    p1 = SyntheticLM(512, batch=4, seq=16, seed=3)
    p2 = SyntheticLM(512, batch=4, seq=16, seed=3)
    b1, b2 = p1.batch_at(17), p2.batch_at(17)
    assert bool(jnp.all(b1["tokens"] == b2["tokens"]))
    assert not bool(jnp.all(p1.batch_at(18)["tokens"] == b1["tokens"]))
    # labels are next-token shifted view of the same stream
    assert bool(jnp.all(b1["labels"][:, :-1] == b1["tokens"][:, 1:]))


def test_synthetic_lm_is_learnable():
    """The affine rule is visible: next token equals perm[tok] 90% of times."""
    p = SyntheticLM(128, batch=8, seq=64, seed=0, noise=0.1)
    b = p.batch_at(0)
    perm = p._rule()
    match = jnp.mean((perm[b["tokens"]] == b["labels"]).astype(jnp.float32))
    assert float(match) > 0.8


def test_token_pipeline_shapes():
    p = TokenPipeline(1000, batch=2, seq=8)
    b = p.batch_at(0)
    assert b["tokens"].shape == (2, 8) and b["labels"].shape == (2, 8)
    assert int(b["tokens"].max()) < 1000
