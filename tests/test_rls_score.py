"""Ladder-level parity + determinism suite for the fused RLS-score path.

Holds every backend's ``rls_scores`` seam to the pre-fusion oracle
(``repro.kernels.rls_score.ref``) across all registered kernel families,
guards the jitted ladder against retraces, and pins the one-seed-spelling
PRNG convention across every ``repro.api`` sampler.
"""
from __future__ import annotations

import importlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import (BlessRSampler, BlessSampler, ChenYangSampler,
                       SqueakSampler, UniformSampler, as_prng_key,
                       make_kernel)
from repro.core import resolve_backend
from repro.core.chen_yang import fast_spectral_rls

# the package re-exports the *function* bless under the submodule's name;
# the retrace guard needs the module itself for its _LADDER_TRACES counter
bless_mod = importlib.import_module("repro.core.bless")
from repro.core.sampling import gumbel_topk
from repro.kernels.rls_score import rls_score_ref

FAMILIES = ["gaussian", "laplacian", "linear", "matern32", "cauchy"]
BACKENDS = ["jnp", "pallas", "sharded"]


def _problem(seed=0, n=96, m=24, mbuf=32, d=6, lam=1e-2):
    """A candidate set + padded center set exercising mask and reg padding."""
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (n, d))
    idx = jax.random.permutation(jax.random.PRNGKey(seed + 1), n)[:mbuf]
    z = x[idx]
    mask = jnp.arange(mbuf) < m
    weight = jnp.where(mask, 0.5 + jax.random.uniform(
        jax.random.PRNGKey(seed + 2), (mbuf,)), 1.0)
    lamn = jnp.asarray(lam * n, jnp.float32)
    reg = jnp.where(mask, lamn * weight, 1.0)
    return x, z, mask, reg, lamn


@pytest.mark.parametrize("family", FAMILIES)
@pytest.mark.parametrize("backend", BACKENDS)
def test_rls_scores_matches_prefusion_ref(family, backend):
    kernel = make_kernel(family, sigma=1.5, kappa_sq=50.0)
    x, z, mask, reg, lamn = _problem()
    got = resolve_backend(backend).rls_scores(kernel, x, z, mask, reg, lamn)
    want = rls_score_ref(kernel, x, z, mask, reg, lamn)
    assert got.shape == want.shape == (x.shape[0],)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=5e-3, atol=5e-4)


@pytest.mark.parametrize("backend", BACKENDS)
def test_rls_scores_empty_center_set_degenerates(backend):
    """All-false mask zeroes the quadform: s = K_ii / (lam n) exactly."""
    kernel = make_kernel("gaussian", sigma=1.5)
    x, z, _, _, lamn = _problem()
    mask = jnp.zeros(z.shape[0], bool)
    reg = jnp.ones(z.shape[0], jnp.float32)
    got = resolve_backend(backend).rls_scores(kernel, x, z, mask, reg, lamn)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(kernel.diag(x) / lamn),
                               rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("family", FAMILIES)
def test_cross_unfused_is_elementwise_identical(family):
    """The blocked-epilogue path must not change a single bit of output."""
    kernel = make_kernel(family, sigma=2.0)
    z = jax.random.normal(jax.random.PRNGKey(1), (40, 5))
    for n in (512, 97):  # blocked path (n % 8 == 0, n >= 512) and plain path
        x = jax.random.normal(jax.random.PRNGKey(0), (n, 5))
        fused = jax.jit(kernel.cross)(x, z)
        unfused = jax.jit(kernel.cross_unfused)(x, z)
        np.testing.assert_array_equal(np.asarray(fused), np.asarray(unfused))


def _ladder_data(n=300, d=4, seed=3):
    key = jax.random.PRNGKey(seed)
    centers = jax.random.normal(key, (8, d)) * 3.0
    assign = jax.random.randint(jax.random.PRNGKey(seed + 1), (n,), 0, 8)
    return centers[assign] + 0.3 * jax.random.normal(
        jax.random.PRNGKey(seed + 2), (n, d))


@pytest.mark.parametrize("alg", ["bless", "bless_r"])
def test_ladder_zero_retrace_on_repeat(alg):
    """A second *identical* ladder run must not retrace any jitted level.

    (A different key may legitimately retrace: acceptance counts move the
    bucketed per-level buffer sizes. Identical inputs must be all cache
    hits — the bucketing exists to make the shape set finite, and scalar
    level parameters ride as weak-typed Python scalars.)
    """
    x = _ladder_data()
    kernel = make_kernel("gaussian", sigma=1.5)
    run = getattr(bless_mod, alg)
    run(jax.random.PRNGKey(0), x, kernel, 1e-2, backend="jnp")
    before = bless_mod._LADDER_TRACES
    out = run(jax.random.PRNGKey(0), x, kernel, 1e-2, backend="jnp")
    assert bless_mod._LADDER_TRACES == before
    assert int(out.final.centers.count) > 0


SAMPLERS = [
    BlessSampler(lam=3e-2, q2=2.0, q1=2.0),
    BlessRSampler(lam=3e-2, q2=2.0),
    SqueakSampler(lam=3e-2, m_cap=64),
    ChenYangSampler(m=48, lam=3e-2),
    UniformSampler(m=48),
]


@pytest.mark.parametrize("sampler", SAMPLERS,
                         ids=lambda s: type(s).__name__)
def test_sampler_seed_convention(sampler):
    """One PRNG convention: int seed, typed key and legacy PRNGKey all
    draw the identical center set, and re-running a seed is deterministic."""
    x = _ladder_data(n=260)
    kernel = make_kernel("gaussian", sigma=1.5)
    spellings = [7, jax.random.key(7), jax.random.PRNGKey(7)]
    sets = [sampler.sample(k, x, kernel, backend="jnp") for k in spellings]
    ref = sets[0]
    for cs in sets[1:]:
        np.testing.assert_array_equal(np.asarray(cs.idx), np.asarray(ref.idx))
        np.testing.assert_array_equal(np.asarray(cs.weight),
                                      np.asarray(ref.weight))
        assert int(cs.count) == int(ref.count)
    other = sampler.sample(8, x, kernel, backend="jnp")
    assert (other.idx.shape != ref.idx.shape
            or not np.array_equal(np.asarray(other.idx), np.asarray(ref.idx)))


def test_as_prng_key_spellings_agree():
    base = as_prng_key(5)
    assert jnp.issubdtype(base.dtype, jax.dtypes.prng_key)
    for other in (as_prng_key(jax.random.key(5)),
                  as_prng_key(jax.random.PRNGKey(5))):
        assert np.array_equal(
            np.asarray(jax.random.key_data(base)),
            np.asarray(jax.random.key_data(other)))


def test_chen_yang_scores_track_exact_rls():
    """Spectral estimates correlate with exact RLS and land in (0, 1]."""
    from repro.core.leverage import exact_rls

    x = _ladder_data(n=220)
    kernel = make_kernel("gaussian", sigma=1.5)
    lam = 1e-2
    est = fast_spectral_rls(jax.random.key(0), kernel, x, lam, backend="jnp")
    exact = exact_rls(kernel, x, lam)
    est, exact = np.asarray(est), np.asarray(exact)
    assert est.shape == (220,)
    assert np.all(est > 0.0) and np.all(est <= 1.0 + 1e-6)
    ratio = est / exact
    assert 1 / 3 < np.median(ratio) < 3.0
    assert np.corrcoef(est, exact)[0, 1] > 0.5


def test_gumbel_topk_is_a_weighted_distinct_draw():
    w = jnp.asarray([10.0, 1.0, 1.0, 1.0, 10.0, 1.0])
    hits = np.zeros(6)
    for s in range(200):
        sel = np.asarray(gumbel_topk(jax.random.key(s), w, 2))
        assert len(set(sel.tolist())) == 2  # without replacement
        hits[sel] += 1
    assert hits[0] + hits[4] > hits[1] + hits[2] + hits[3] + hits[5]
