"""Per-kernel Pallas (interpret=True) vs pure-jnp oracle, swept over
shapes (incl. non-divisible tails) and dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.gram.ops import gram, gram_reference
from repro.kernels.quadform.ops import quadform, quadform_reference
from repro.kernels.falkon_matvec.ops import falkon_matvec
from repro.kernels.falkon_matvec.ref import falkon_matvec_ref
from repro.kernels.flash_attention.ops import flash_attention, flash_attention_reference


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("n,m,d", [(256, 256, 128), (300, 130, 17), (64, 512, 64), (1000, 77, 3)])
@pytest.mark.parametrize("kind", ["gaussian", "laplacian", "linear", "matern32", "cauchy"])
def test_gram_shapes(n, m, d, kind):
    x = jax.random.normal(jax.random.PRNGKey(0), (n, d))
    z = jax.random.normal(jax.random.PRNGKey(1), (m, d))
    out = gram(x, z, 1.3, kind=kind, interpret=True)
    ref = gram_reference(x, z, 1.3, kind=kind)
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gram_dtypes(dtype):
    x = jax.random.normal(jax.random.PRNGKey(0), (257, 40)).astype(dtype)
    z = jax.random.normal(jax.random.PRNGKey(1), (129, 40)).astype(dtype)
    out = gram(x, z, 2.0, interpret=True).astype(jnp.float32)
    ref = gram_reference(x, z, 2.0).astype(jnp.float32)
    np.testing.assert_allclose(out, ref, **_tol(dtype))


@pytest.mark.parametrize("n,m", [(256, 256), (300, 200), (100, 515), (1024, 64)])
def test_quadform_shapes(n, m):
    g = jax.random.normal(jax.random.PRNGKey(0), (n, m))
    w = jax.random.normal(jax.random.PRNGKey(1), (m, m))
    w = w @ w.T / m
    out = quadform(g, w, interpret=True)
    ref = quadform_reference(g, w)
    np.testing.assert_allclose(out, ref, rtol=3e-5, atol=3e-4)


@pytest.mark.parametrize("n,m,d,bn", [(512, 128, 128, 256), (700, 130, 17, 256), (256, 515, 8, 128)])
def test_falkon_matvec_shapes(n, m, d, bn):
    x = jax.random.normal(jax.random.PRNGKey(0), (n, d))
    z = jax.random.normal(jax.random.PRNGKey(1), (m, d))
    v = jax.random.normal(jax.random.PRNGKey(2), (m,))
    out = falkon_matvec(x, z, v, 1.5, interpret=True, bn=bn)
    ref = falkon_matvec_ref(x, z, v, 1.0 / (2 * 1.5**2))
    np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-4 * float(jnp.abs(ref).max()))


@pytest.mark.parametrize("kind", ["laplacian", "linear", "matern32", "cauchy"])
def test_falkon_matvec_all_families(kind):
    """The fused CG matvec consumes every registered family's epilogue."""
    from repro.families import get_family

    x = jax.random.normal(jax.random.PRNGKey(0), (300, 12))
    z = jax.random.normal(jax.random.PRNGKey(1), (70, 12))
    v = jax.random.normal(jax.random.PRNGKey(2), (70,))
    out = falkon_matvec(x, z, v, 1.5, kind=kind, interpret=True, bn=256)
    ref = falkon_matvec_ref(x, z, v, float(get_family(kind).inv_scale(1.5)), kind=kind)
    np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-4 * float(jnp.abs(ref).max()))


@pytest.mark.parametrize(
    "hq,hkv,s,d,causal",
    [(4, 4, 256, 128, True), (8, 2, 300, 64, True), (8, 1, 512, 80, True),
     (4, 4, 300, 64, False), (2, 2, 128, 128, False)],
)
def test_flash_attention_shapes(hq, hkv, s, d, causal):
    q = jax.random.normal(jax.random.PRNGKey(0), (2, hq, s, d))
    k = jax.random.normal(jax.random.PRNGKey(1), (2, hkv, s, d))
    v = jax.random.normal(jax.random.PRNGKey(2), (2, hkv, s, d))
    out = flash_attention(q, k, v, causal=causal, bq=128, bk=128, interpret=True)
    ref = flash_attention_reference(q, k, v, causal=causal)
    np.testing.assert_allclose(out, ref, rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_dtypes(dtype):
    q = jax.random.normal(jax.random.PRNGKey(0), (1, 4, 256, 128)).astype(dtype)
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 2, 256, 128)).astype(dtype)
    v = jax.random.normal(jax.random.PRNGKey(2), (1, 2, 256, 128)).astype(dtype)
    out = flash_attention(q, k, v, interpret=True).astype(jnp.float32)
    ref = flash_attention_reference(q, k, v).astype(jnp.float32)
    np.testing.assert_allclose(out, ref, **_tol(dtype))


def test_falkon_matvec_plugs_into_cg():
    """The fused kernels serve falkon_fit through the Pallas backend."""
    from repro.core import PallasBackend, falkon_fit, make_kernel, nystrom_krr

    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (400, 6))
    y = jnp.sin(x[:, 0])
    z = x[:80]
    kern = make_kernel("gaussian", sigma=1.5)
    fk = falkon_fit(kern, x, y, z, 1e-3, iters=25,
                    backend=PallasBackend(interpret=True, bn=256))
    ny = nystrom_krr(kern, x, y, z, 1e-3)
    pf, pn = fk.predict(x), ny.predict(x)
    assert float(jnp.linalg.norm(pf - pn) / jnp.linalg.norm(pn)) < 1e-3


@pytest.mark.parametrize("n,m,d", [(512, 128, 64), (700, 130, 17)])
def test_knm_t_kernel_shapes(n, m, d):
    from repro.kernels.falkon_matvec.ops import knm_t
    from repro.kernels.falkon_matvec.ref import knm_t_ref

    x = jax.random.normal(jax.random.PRNGKey(0), (n, d))
    z = jax.random.normal(jax.random.PRNGKey(1), (m, d))
    y = jax.random.normal(jax.random.PRNGKey(2), (n,))
    out = knm_t(x, z, y, 1.5, interpret=True, bn=256)
    ref = knm_t_ref(x, z, y, 1.0 / (2 * 1.5**2))
    np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-4 * float(jnp.abs(ref).max()))


@pytest.mark.parametrize("s,chunk,h,p,n", [(96, 32, 4, 8, 16), (80, 32, 2, 16, 8),
                                           (128, 128, 8, 8, 16)])
def test_ssd_kernel_shapes(s, chunk, h, p, n):
    from repro.kernels.ssd.ops import ssd, ssd_reference

    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    x = jax.random.normal(ks[0], (2, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (2, s, h)))
    a = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    b = jax.random.normal(ks[3], (2, s, n)) * 0.5
    c = jax.random.normal(ks[4], (2, s, n)) * 0.5
    y, st = ssd(x, dt, a, b, c, chunk=chunk, interpret=True)
    yr, str_ = ssd_reference(x, dt, a, b, c, chunk=16)  # 16 divides every s
    np.testing.assert_allclose(y, yr, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(st, str_, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_kernel_dtypes(dtype):
    from repro.kernels.ssd.ops import ssd, ssd_reference

    ks = jax.random.split(jax.random.PRNGKey(1), 5)
    x = jax.random.normal(ks[0], (1, 64, 4, 8)).astype(dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (1, 64, 4))).astype(dtype)
    a = -jnp.exp(jax.random.normal(ks[2], (4,)) * 0.3)
    b = (jax.random.normal(ks[3], (1, 64, 16)) * 0.5).astype(dtype)
    c = (jax.random.normal(ks[4], (1, 64, 16)) * 0.5).astype(dtype)
    y, _ = ssd(x, dt, a, b, c, chunk=32, interpret=True)
    yr, _ = ssd_reference(x, dt, a, b, c, chunk=32)
    tol = dict(rtol=3e-2, atol=3e-2) if dtype == jnp.bfloat16 else dict(rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(y.astype(jnp.float32), yr.astype(jnp.float32), **tol)
