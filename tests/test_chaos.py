"""Chaos suite (marker: chaos): drives the repro.testing.faults injection
points end-to-end through the production hook sites and asserts the §9
fences *recover or isolate* — a NaN Gram tile fails only its own wave (and
only its own request after bisection), a Poisson overload sheds/degrades
while keeping served p99 inside the SLO, an indefinite K_MM either rides
the jitter ladder or raises, and a dying primary backend falls back to the
jnp streamer with correct results. The §11 durability scenarios live here
too: streamed fits killed at chunk barriers resume bit-identical, torn
checkpoints are invisible to latest_step, poisoned appends are fenced, and
hot swaps under Poisson load drop or misroute zero requests. Runs in its
own CI job (-m chaos)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import (AsyncKrrServer, FalkonRegressor, FitConfig,
                       NystromRegressor, ServeConfig)
from repro.core import falkon_fit, make_kernel
from repro.core import health
from repro.core.backend import GuardedBackend, JnpBackend
from repro.core.nystrom import nystrom_krr
from repro.serving.async_krr import RequestStatus
from repro.testing import faults

pytestmark = pytest.mark.chaos

KERN = make_kernel("gaussian", sigma=1.5)


@pytest.fixture(autouse=True)
def _clean():
    health.clear_events()
    assert not faults.active()  # no fault leaks between tests
    yield
    assert not faults.active()
    health.clear_events()


@pytest.fixture(scope="module")
def model():
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (300, 5))
    y = jnp.sin(2 * x[:, 0]) + 0.3 * x[:, 1]
    return falkon_fit(KERN, x, y, x[:40], 1e-3, iters=12, backend="jnp")


def _reqs(seeds_and_sizes, d=5):
    return [jax.random.normal(jax.random.PRNGKey(s), (r, d))
            for s, r in seeds_and_sizes]


# -- fault registry hygiene --------------------------------------------------


def test_registry_rejects_unknown_points():
    with pytest.raises(ValueError, match="unknown fault point"):
        with faults.fault("gram.nan_tlie"):  # typo must not arm nothing
            pass
    with faults.fault("backend.error", times=1):
        with pytest.raises(RuntimeError, match="already armed"):
            with faults.fault("backend.error"):
                pass


def test_times_budget_exhausts():
    with faults.fault("backend.error", times=2) as f:
        for _ in range(2):
            with pytest.raises(faults.FaultInjected):
                faults.raise_if()
        faults.raise_if()  # third hit: exhausted, no raise
        assert f.fired == 2 and f.exhausted
    faults.raise_if()  # disarmed after the context


# -- NaN Gram tile through serving -------------------------------------------


def test_transient_nan_wave_retried_and_recovers(model):
    """A NaN tile poisons one wave (times=1): the finite fence catches it,
    the wave is bisected, the retries run clean, every request is DONE."""
    srv = AsyncKrrServer(model, config=ServeConfig(min_bucket=16))
    reqs = _reqs([(1, 8), (2, 8), (3, 8), (4, 8)])
    rids = [srv.submit(q) for q in reqs]
    with faults.fault("gram.nan_tile", times=1):
        srv.run_until_idle()
    for rid, q in zip(rids, reqs):
        assert srv.status(rid) == RequestStatus.DONE
        np.testing.assert_allclose(srv.result(rid), model.predict(q),
                                   rtol=1e-6, atol=1e-6)
        assert bool(jnp.all(jnp.isfinite(srv.result(rid))))
    assert srv.stats["wave_failures"] == 1
    assert srv.stats["splits"] >= 1
    assert health.events("wave_failure")


def test_persistent_nan_fails_only_its_wave(model):
    """A fault outlasting the bisection (times=3 covers wave + both
    singleton retries of a 2-request wave) fails exactly those requests;
    traffic after the fault clears is served normally."""
    srv = AsyncKrrServer(model, config=ServeConfig(min_bucket=16))
    r1, r2 = (srv.submit(q) for q in _reqs([(1, 8), (2, 8)]))
    with faults.fault("gram.nan_tile", times=3):
        srv.run_until_idle()
    assert srv.status(r1) == RequestStatus.FAILED
    assert srv.status(r2) == RequestStatus.FAILED
    assert srv.result(r1) is None and srv.result(r2) is None
    assert "non-finite" in srv._requests[r1].error
    r3 = srv.submit(_reqs([(3, 8)])[0])
    srv.run_until_idle()
    assert srv.status(r3) == RequestStatus.DONE  # blast radius: 2 requests


def test_nan_isolated_to_one_request_in_big_wave(model):
    """NaN rows land in the padded wave head every retry; bisection still
    narrows the failure until clean sub-waves serve — DONE requests must
    be finite and exact."""
    srv = AsyncKrrServer(model, config=ServeConfig(min_bucket=16))
    reqs = _reqs([(s, 4) for s in range(8)])
    rids = [srv.submit(q) for q in reqs]
    with faults.fault("gram.nan_tile", times=4, rows=2):
        srv.run_until_idle()
    done = [r for r in rids if srv.status(r) == RequestStatus.DONE]
    failed = [r for r in rids if srv.status(r) == RequestStatus.FAILED]
    assert len(done) + len(failed) == 8 and done  # no request lost or hung
    for rid, q in zip(rids, reqs):
        if srv.status(rid) == RequestStatus.DONE:
            np.testing.assert_allclose(srv.result(rid), model.predict(q),
                                       rtol=1e-6, atol=1e-6)


def test_dispatch_error_wave_isolated(model):
    """An exception raised *at dispatch* (not at completion) goes through
    the same bisection isolation — it must never escape step()."""
    srv = AsyncKrrServer(model, config=ServeConfig(min_bucket=16))
    rids = [srv.submit(q) for q in _reqs([(1, 8), (2, 8)])]
    with faults.fault("backend.error", times=1):
        srv.run_until_idle()
    assert all(srv.status(r) == RequestStatus.DONE for r in rids)
    assert srv.stats["wave_failures"] == 1


# -- overload ----------------------------------------------------------------


def test_poisson_overload_sheds_and_keeps_slo(model):
    """Poisson arrivals far above capacity, in virtual time: the bounded
    queue sheds/expires the excess, the SLO breach degrades to the cheap
    fallback, and the p99 of *served* waves lands back inside the SLO."""
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (100, 5))
    fallback = falkon_fit(KERN, x, jnp.sin(x[:, 0]), x[:8], 1e-2, iters=4,
                          backend="jnp")
    clk = faults.VirtualClock()
    slo = 0.10
    # recover_factor is set sticky-low: recovering mid-storm would re-admit
    # the slow primary and flap (the hysteresis band itself is exercised in
    # test_async_serving.py) — here we assert the degraded steady state.
    srv = AsyncKrrServer(
        model, fallback_model=fallback, clock=clk,
        config=ServeConfig(min_bucket=16, max_wave=32, max_queue_rows=64,
                           overflow="shed_oldest", deadline=2.0, slo=slo,
                           slo_window=8, recover_factor=0.01))
    rng = np.random.default_rng(0)
    arrivals = np.cumsum(rng.exponential(0.005, size=120))  # ~200 req/s

    # primary waves cost 0.2 virtual s (slo-breaching); fallback waves are
    # 10x cheaper — centers 8 vs 40 keys the cost off the serving model
    def cost(rows, centers):
        return 0.2 if centers >= 40 else 0.02

    with faults.fault("dispatch.latency", seconds=cost, advance=clk.advance):
        i = 0
        while i < len(arrivals) or srv._queue or srv._inflight:
            while i < len(arrivals) and arrivals[i] <= clk():
                try:
                    srv.submit(_reqs([(i, 8)])[0])
                except Exception:
                    pass  # QueueFull under reject would be fine too
                i += 1
            if not srv.step() and i < len(arrivals):
                # idle until the next arrival (dispatch latency may already
                # have moved the clock past it — never step backwards)
                clk.advance(max(0.0, arrivals[i] - clk()))
    assert srv.stats["shed"] > 0 or srv.stats["expired"] > 0  # load was shed
    assert srv.stats["degraded_waves"] > 0  # degradation engaged
    assert health.events("slo_degrade")
    assert srv.degraded  # storm still on: the server stays degraded
    # in the degraded steady state the served (fallback) waves meet the SLO
    assert srv.p99_latency() <= slo
    served = [r for r in srv._requests.values()
              if r.status == RequestStatus.DONE]
    assert served  # the system kept serving under overload


# -- indefinite K_MM ---------------------------------------------------------


def test_indefinite_kmm_succeeds_or_raises_never_nan():
    """Def. 4 solve with K_MM pushed indefinite at several severities: the
    outcome is a finite model or FactorizationError — never NaN output."""
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (200, 4))
    y = jnp.sin(x[:, 0])
    for shift in (0.5, 2.0, 50.0):
        health.clear_events()
        try:
            with faults.fault("kmm.indefinite", shift=shift):
                m = nystrom_krr(KERN, x, y, x[:24], 1e-6, backend="jnp")
        except health.HealthError:
            continue  # raising is an accepted outcome; NaN is not
        pred = m.predict(x[:16])
        assert bool(jnp.all(jnp.isfinite(m.alpha)))
        assert bool(jnp.all(jnp.isfinite(pred)))


def test_indefinite_kmm_through_estimator():
    key = jax.random.PRNGKey(1)
    x = jax.random.normal(key, (150, 3))
    est = NystromRegressor(config=FitConfig(lam=1e-5, backend="jnp"))
    try:
        with faults.fault("kmm.indefinite", shift=3.0):
            est.fit(x, jnp.cos(x[:, 0]))
    except health.HealthError:
        return
    assert bool(jnp.all(jnp.isfinite(est.predict(x[:8]))))


# -- backend fallback --------------------------------------------------------


def test_guarded_backend_falls_back_per_dispatch():
    """Every primary dispatch dies (FaultyBackend + backend.error): the
    GuardedBackend serves each call from the jnp fallback, records the
    fallbacks, and the results are exact."""
    gb = GuardedBackend(primary=faults.FaultyBackend(JnpBackend()),
                        fallback=JnpBackend())
    ref = JnpBackend()
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (64, 3))
    z = x[:16]
    v = jnp.ones((16,))
    with faults.fault("backend.error"):
        with pytest.warns(RuntimeWarning, match="falling back to jnp"):
            g = gb.gram_block(KERN, x, z)
        mv = gb.knm_matvec(KERN, x, z, v)
    np.testing.assert_allclose(g, ref.gram_block(KERN, x, z), rtol=1e-6)
    np.testing.assert_allclose(mv, ref.knm_matvec(KERN, x, z, v), rtol=1e-6)
    evts = health.events("backend_fallback")
    assert len(evts) == 2 and {e["method"] for e in evts} == {
        "gram_block", "knm_matvec"}


def test_guarded_backend_fit_survives_dying_primary():
    """A whole FALKON fit through a guarded, dying primary matches the
    clean-backend fit (the guarded path is host-driven, so every dispatch
    is individually recoverable)."""
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (200, 4))
    y = jnp.sin(2 * x[:, 0])
    clean = falkon_fit(KERN, x, y, x[:24], 1e-3, iters=8, backend="jnp")
    gb = GuardedBackend(primary=faults.FaultyBackend(JnpBackend()),
                        fallback=JnpBackend())
    import warnings as _w
    with faults.fault("backend.error"), _w.catch_warnings():
        _w.simplefilter("ignore", RuntimeWarning)
        m = falkon_fit(KERN, x, y, x[:24], 1e-3, iters=8, backend=gb)
    # predict outside the fault scope: its own dispatch hook would fire too.
    # Tolerance: the guarded fit takes the host CG path while the clean jnp
    # fit is the fused jit solve — same math, different fp32 rounding.
    pred = m.predict(x[:16], backend="jnp")
    np.testing.assert_allclose(pred, clean.predict(x[:16]),
                               rtol=5e-3, atol=5e-3)
    assert health.events("backend_fallback")


def test_guarded_backend_happy_path_uses_primary():
    """With no fault armed the primary serves and no fallback is recorded
    (the guard is pass-through, not a silent rewrite to jnp)."""
    gb = GuardedBackend(primary=JnpBackend(), fallback=JnpBackend())
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (32, 3))
    out = gb.gram_block(KERN, x, x[:8])
    assert out.shape == (32, 8)
    assert health.events("backend_fallback") == []


def test_faulty_backend_delegates_when_quiet(model):
    """FaultyBackend with nothing armed is a transparent proxy — predict
    through it matches the plain backend exactly."""
    fb = faults.FaultyBackend(JnpBackend())
    q = _reqs([(5, 8)])[0]
    np.testing.assert_allclose(model.predict(q, backend=fb), model.predict(q),
                               rtol=1e-7, atol=1e-7)


# -- durable online FALKON (DESIGN.md §11) ------------------------------------


def _online_data(n=2400, d=4):
    rng = np.random.default_rng(0)
    x = rng.normal(size=(n, d)).astype(np.float32)
    y = (np.sin(2 * x[:, 0]) + 0.3 * x[:, 1]).astype(np.float32)
    return x, y


@pytest.mark.parametrize("stage,skip", [
    ("post_rename", 0),   # killed right after the 1st barrier committed
    ("post_rename", 1),   # killed mid-run after the 2nd barrier
    ("pre_rename", 1),    # killed inside the torn window itself
])
def test_streamed_fit_killed_then_resumed_bit_identical(tmp_path, stage, skip):
    """A streamed fit killed at an arbitrary chunk barrier resumes from the
    last complete checkpoint and replays into a BIT-identical alpha — the
    fp32 accumulators round-trip exactly and chunk-order accumulation is
    deterministic, so resumed == uninterrupted, not just close."""
    from repro.api import resumable_streamed_fit
    from repro.stream import ChunkStore

    x, y = _online_data()
    centers = jnp.asarray(x[:48])
    store = ChunkStore(x, y, chunk=512)  # 5 chunks; barriers at 2, 4, 5
    ref = resumable_streamed_fit(KERN, store, centers=centers, lam=1e-3,
                                 iters=25, ckpt_dir=str(tmp_path / "ref"),
                                 ckpt_every=2)
    killed = tmp_path / "killed"
    with faults.fault("ckpt.torn_write", stage=stage, skip=skip, times=1):
        with pytest.raises(faults.FaultInjected):
            resumable_streamed_fit(KERN, store, centers=centers, lam=1e-3,
                                   iters=25, ckpt_dir=str(killed),
                                   ckpt_every=2)
    resumed = resumable_streamed_fit(KERN, store, centers=centers, lam=1e-3,
                                     iters=25, ckpt_dir=str(killed),
                                     ckpt_every=2)
    assert bool(jnp.all(resumed.alpha == ref.alpha))  # bitwise
    assert health.events("durable_fit_resume")  # it really did resume


def test_torn_checkpoint_never_observed_by_latest_step(tmp_path):
    """A write killed between the complete temp dir and the atomic rename
    leaves a ``.tmp`` turd that ``latest_step`` must never report, and any
    step it does report must restore completely."""
    from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint

    tree = {"h": jnp.arange(12.0).reshape(3, 4)}
    save_checkpoint(str(tmp_path), 1, tree)
    with faults.fault("ckpt.torn_write", stage="pre_rename", times=1):
        with pytest.raises(faults.FaultInjected):
            save_checkpoint(str(tmp_path), 2, {"h": jnp.ones((3, 4))})
    import os
    assert os.path.isdir(tmp_path / "step_00000002.tmp")  # the torn write
    assert latest_step(str(tmp_path)) == 1  # never the torn step
    _, loaded = restore_checkpoint(str(tmp_path), tree)
    assert bool(jnp.all(loaded["h"] == tree["h"]))


def test_online_corrupt_row_rejected_by_ingest_fence():
    """``online.corrupt_row`` poisons an appended batch upstream of the
    always-on finite-input fence: the append raises, the store and the
    accumulators are untouched, and the next clean append succeeds."""
    from repro.api import OnlineFalkon

    x, y = _online_data(n=1200)
    of = OnlineFalkon(KERN, x[:48], 1e-3, x=x[:800], y=y[:800], chunk=256)
    h0, b0 = of._h, of._b
    with faults.fault("online.corrupt_row", row=2):
        with pytest.raises(health.NonFiniteError):
            of.append(x[800:900], y[800:900])
    assert of.counters["rejected"] == 1 and of.counters["appends"] == 0
    assert of.store.shape[0] == 800
    assert bool(jnp.all(of._h == h0)) and bool(jnp.all(of._b == b0))
    assert health.events("online_append_rejected")
    of.append(x[800:900], y[800:900])  # disarmed: clean batch lands
    assert of.counters["appends"] == 1


def test_swap_under_poisson_load_zero_dropped_zero_misrouted(model):
    """Hot-swap the model mid-storm under virtual-clock Poisson arrivals
    with waves in flight: every clean request completes (zero dropped /
    failed), and every result matches exactly the model generation its
    request was tagged with — no wave ever mixes generations."""
    key = jax.random.PRNGKey(9)
    x2 = jax.random.normal(key, (300, 5))
    m2 = falkon_fit(KERN, x2, jnp.cos(x2[:, 0]), x2[:40], 1e-3, iters=12,
                    backend="jnp")
    clk = faults.VirtualClock()
    # no queue cap / deadline: nothing may be shed or expired — every
    # request must be DONE for the scenario to count as zero-downtime
    srv = AsyncKrrServer(model, clock=clk,
                         config=ServeConfig(min_bucket=16, max_wave=32,
                                            max_inflight=2))
    rng = np.random.default_rng(1)
    arrivals = np.cumsum(rng.exponential(0.01, size=80))
    reqs = _reqs([(s, int(r)) for s, r in
                  zip(range(80), rng.integers(1, 9, size=80))])
    swapped = False
    with faults.fault("dispatch.latency", seconds=0.05, advance=clk.advance):
        i = 0
        while i < len(arrivals) or srv._queue or srv._inflight:
            while i < len(arrivals) and arrivals[i] <= clk():
                srv.submit(reqs[i])
                i += 1
            if i >= 40 and not swapped:
                assert srv.swap_model(m2)  # mid-storm, waves in flight
                swapped = True
            if not srv.step() and i < len(arrivals):
                clk.advance(max(0.0, arrivals[i] - clk()))
    assert swapped and srv.stats["swaps"] == 1
    by_version = {0: model, 1: m2}
    versions_seen = set()
    for rid in range(len(reqs)):
        req = srv._requests[rid]
        assert req.status == RequestStatus.DONE  # zero dropped/failed
        versions_seen.add(req.model_version)
        np.testing.assert_allclose(        # zero misrouted: result matches
            np.asarray(req.result),        # its tagged generation exactly
            np.asarray(by_version[req.model_version].predict(reqs[rid])),
            rtol=1e-6, atol=1e-6)
    assert versions_seen == {0, 1}  # both generations actually served


def test_poisoned_refresh_cannot_reach_traffic(model):
    """The full online loop under chaos: a refit gone NaN is rejected at
    the swap probe, the incumbent keeps serving, and a later healthy refit
    swaps in cleanly."""
    import dataclasses

    srv = AsyncKrrServer(model, config=ServeConfig(min_bucket=16))
    poisoned = dataclasses.replace(model,
                                   alpha=model.alpha.at[3].set(jnp.nan))
    assert not srv.swap_model(poisoned)
    q = _reqs([(7, 8)])[0]
    rid = srv.submit(q)
    srv.run_until_idle()
    assert srv.status(rid) == RequestStatus.DONE
    assert srv._requests[rid].model_version == 0
    assert srv.stats["swaps_rejected"] == 1
    healthy = dataclasses.replace(model, alpha=model.alpha * 0.5)
    assert srv.swap_model(healthy)
    assert srv.stats["model_version"] == 1
