"""Checkpointing (atomicity, async, gc, bf16 roundtrip) and fault-tolerance
runtime (straggler detection, restart supervision, gradient compression)."""
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.checkpoint import (AsyncCheckpointer, latest_step,
                              restore_checkpoint, save_checkpoint)
from repro.runtime import FaultTolerantLoop, HeartbeatMonitor
from repro.runtime.compress import int8_compress, int8_decompress


def _tree():
    return {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.bfloat16),
                  "d": jnp.asarray(3, jnp.int32)}}


def test_roundtrip_including_bf16(tmp_path):
    t = _tree()
    save_checkpoint(str(tmp_path), 7, t)
    step, t2 = restore_checkpoint(str(tmp_path), t)
    assert step == 7
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(t2)):
        assert a.dtype == b.dtype
        assert bool(jnp.all(a == b))


def test_latest_step_ignores_tmp(tmp_path):
    save_checkpoint(str(tmp_path), 3, _tree())
    os.makedirs(tmp_path / "step_00000009.tmp")
    assert latest_step(str(tmp_path)) == 3


def test_bf16_manifest_records_uint16_view_and_restores_true_bf16(tmp_path):
    """bf16 round-trip lockdown: the manifest records BOTH the logical
    dtype and the on-disk uint16 view, and restore hands back true bf16
    (not a raw uint16 view) with bit-identical payload."""
    import json

    import ml_dtypes

    t = _tree()
    save_checkpoint(str(tmp_path), 1, t)
    with open(tmp_path / "step_00000001" / "manifest.json") as f:
        manifest = json.load(f)
    rec = manifest["leaves"]["b/c"]
    assert rec["dtype"] == "bfloat16" and rec["stored_dtype"] == "uint16"
    fp32 = manifest["leaves"]["a"]
    assert fp32["dtype"] == fp32["stored_dtype"] == "float32"
    _, t2 = restore_checkpoint(str(tmp_path), t)
    assert t2["b"]["c"].dtype == ml_dtypes.bfloat16
    assert bool(jnp.all(t2["b"]["c"] == t["b"]["c"]))


def test_restore_refuses_tampered_leaf_dtype(tmp_path):
    """A leaf whose on-disk dtype disagrees with the recorded stored_dtype
    (bit rot, incompatible writer) is refused, never reinterpreted."""
    save_checkpoint(str(tmp_path), 1, _tree())
    path = tmp_path / "step_00000001"
    import json

    with open(path / "manifest.json") as f:
        manifest = json.load(f)
    fname = manifest["leaves"]["b/c"]["file"]  # the bf16-as-uint16 leaf
    np.save(path / fname, np.load(path / fname).astype(np.float64))
    with pytest.raises(ValueError, match="stored_dtype"):
        restore_checkpoint(str(tmp_path), _tree())


def test_crash_window_property_every_stage_leaves_loadable_state(tmp_path):
    """Kill save_checkpoint at EVERY filesystem step: whatever step dies,
    ``latest_step`` only ever sees a complete, restorable checkpoint.

    A ``times=0`` fault is a pure hit counter — one armed pass enumerates
    the crash stages; then each stage k is killed via ``skip=k, times=1``.
    """
    from repro.testing import faults

    base = _tree()
    save_checkpoint(str(tmp_path), 1, base)  # the survivor checkpoint
    with faults.fault("ckpt.torn_write", times=0) as probe:
        save_checkpoint(str(tmp_path), 2, base)
    n_stages = probe.seen
    # tmp dir + one per leaf + pre/post rename (CRASH_STAGES contract)
    assert n_stages == len(jax.tree.leaves(base)) + 3
    for k in range(n_stages):
        ckdir = tmp_path / f"kill_{k}"
        os.makedirs(ckdir)
        save_checkpoint(str(ckdir), 1, base)
        tree2 = {"a": jnp.full((2, 3), 9.0), "b": base["b"]}
        with faults.fault("ckpt.torn_write", times=1, skip=k):
            with pytest.raises(faults.FaultInjected):
                save_checkpoint(str(ckdir), 2, tree2)
        step = latest_step(str(ckdir))
        assert step in (1, 2)  # whatever survived must be complete:
        _, loaded = restore_checkpoint(str(ckdir), base)
        want = base if step == 1 else tree2
        for a, b in zip(jax.tree.leaves(want), jax.tree.leaves(loaded)):
            assert a.dtype == b.dtype and bool(jnp.all(a == b))


def test_async_checkpointer_gc(tmp_path):
    ck = AsyncCheckpointer(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        ck.save(s, _tree())
    ck.wait()
    time.sleep(0.1)
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(tmp_path))
    assert steps == [3, 4]


def test_heartbeat_straggler_detection():
    mon = HeartbeatMonitor(threshold=2.0, window=16)
    for i in range(20):
        mon.record(i, 0.1)
    assert mon.record(20, 0.5) is True
    assert mon.record(21, 0.11) is False
    assert len(mon.stragglers) == 1


def test_fault_tolerant_loop_recovers(tmp_path):
    ck = AsyncCheckpointer(str(tmp_path))
    state0 = {"x": jnp.zeros(())}
    ck.save(0, state0)
    ck.wait()
    fails = {7, 13}

    def inject(step):
        if step in fails:
            fails.discard(step)
            raise RuntimeError("boom")

    def step_fn(st, step):
        return {"x": st["x"] + 1}, {}

    def restore():
        s = latest_step(str(tmp_path))
        _, st = restore_checkpoint(str(tmp_path), state0, step=s)
        return s, st

    loop = FaultTolerantLoop(step_fn, ck, ckpt_every=5, failure_injector=inject)
    final, end = loop.run(state0, 0, 20, restore)
    assert end == 20 and loop.restarts == 2
    assert float(final["x"]) >= 15  # replayed segments re-executed


def test_too_many_failures_raises(tmp_path):
    ck = AsyncCheckpointer(str(tmp_path))
    ck.save(0, {"x": jnp.zeros(())})
    ck.wait()

    def inject(step):
        raise RuntimeError("always")

    loop = FaultTolerantLoop(lambda s, i: (s, {}), ck, max_restarts=2,
                             failure_injector=inject)
    with pytest.raises(RuntimeError):
        loop.run({"x": jnp.zeros(())}, 0, 5,
                 lambda: (0, {"x": jnp.zeros(())}))


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 1000), scale=st.floats(1e-4, 1e3))
def test_int8_quantization_error_bound(seed, scale):
    g = jax.random.normal(jax.random.PRNGKey(seed), (64,)) * scale
    err0 = jnp.zeros_like(g)
    q, s, err = int8_compress(g, err0)
    deq = int8_decompress(q, s)
    assert float(jnp.max(jnp.abs(deq - g))) <= float(s) * 0.5 + 1e-6
    # error feedback: residual equals quantization error exactly
    np.testing.assert_allclose(np.asarray(err), np.asarray(g - deq), rtol=1e-5,
                               atol=1e-7 * scale)


def test_error_feedback_reduces_bias():
    """Repeated EF-compressed sums drift less than naive quantization."""
    g = jnp.full((32,), 0.004)  # well below one int8 step at scale ~0.03
    big = jnp.zeros((32,)).at[0].set(4.0)  # forces a coarse scale
    grads = g + big * 0
    err = jnp.zeros_like(grads)
    acc_ef, acc_naive = jnp.zeros_like(grads), jnp.zeros_like(grads)
    gq = grads.at[0].set(4.0)
    for _ in range(50):
        q, s, err = int8_compress(gq, err)
        acc_ef += int8_decompress(q, s)
        q2, s2, _ = int8_compress(gq, jnp.zeros_like(gq))
        acc_naive += int8_decompress(q2, s2)
    true = gq * 50
    assert float(jnp.abs(acc_ef - true)[1:].max()) < float(jnp.abs(acc_naive - true)[1:].max()) + 1e-5
    assert float(jnp.abs(acc_ef - true).max()) < 0.05
