"""Durable online FALKON (repro/online): incremental append + warm-refit
parity with cold fits, the always-on ingest fence, background center
refresh with delta absorption, ChunkStore growth, and the resumable
streamed fit's checkpoint/refusal contract. The kill/resume chaos
scenarios live in test_chaos.py."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.api import (OnlineFalkon, ResumeMismatchError, UniformSampler,
                       as_prng_key, resumable_streamed_fit)
from repro.checkpoint import checkpoint_extra, latest_step, restore_checkpoint
from repro.core import falkon_fit, health, make_kernel
from repro.online import accumulate
from repro.stream import ChunkStore

KERN = make_kernel("gaussian", sigma=1.5)
# Converged regime on purpose: the accumulator path solves the explicitly
# formed normal equations, so parity with the operator path is only
# meaningful once both CGs have converged (unconverged iterates follow
# different rounding paths); see repro/online/accumulate.py.
LAM, ITERS = 1e-3, 30
N, D, M = 2400, 4, 56


@pytest.fixture(scope="module")
def data():
    rng = np.random.default_rng(0)
    x = rng.normal(size=(N, D)).astype(np.float32)
    y = (np.sin(2 * x[:, 0]) + 0.3 * x[:, 1]).astype(np.float32)
    return x, y


def _pred_rel_err(a, b, xt):
    pa, pb = a.predict(xt), b.predict(xt)
    return float(jnp.max(jnp.abs(pa - pb)) / jnp.max(jnp.abs(pa)))


# -- parity: appends + warm refit vs cold fit on concatenated data -----------


@pytest.mark.parametrize("backend", ["jnp", "stream:pallas"])
def test_append_refit_matches_cold_fit(data, backend):
    x, y = data
    cold = falkon_fit(KERN, jnp.asarray(x), jnp.asarray(y), jnp.asarray(x[:M]),
                      LAM, iters=ITERS)
    of = OnlineFalkon(KERN, x[:M], LAM, x=x[:800], y=y[:800], iters=ITERS,
                      backend=backend, chunk=512)
    for i in range(800, N, 400):
        of.append(x[i:i + 400], y[i:i + 400])
    model = of.refit()
    assert cold.diagnostics.converged and model.diagnostics.converged
    xt = jnp.asarray(np.random.default_rng(1).normal(size=(300, D)),
                     jnp.float32)
    assert _pred_rel_err(cold, model, xt) < 1e-2
    assert of.counters["appends"] == 4 and of.counters["rows"] == N


def test_multi_output_append_refit(data):
    x, y = data
    Y = np.stack([y, -y], axis=1)
    cold = falkon_fit(KERN, jnp.asarray(x), jnp.asarray(Y), jnp.asarray(x[:M]),
                      LAM, iters=ITERS)
    of = OnlineFalkon(KERN, x[:M], LAM, x=x[:1200], y=Y[:1200], iters=ITERS,
                      chunk=512)
    of.append(x[1200:], Y[1200:])
    model = of.refit()
    assert model.alpha.shape == (M, 2)
    xt = jnp.asarray(x[:200])
    assert _pred_rel_err(cold, model, xt) < 1e-2


def test_warm_refit_rides_one_executable(data):
    """Steady-state append+refit cycles must not retrace the accumulator
    solve — each refit is one cached compiled dispatch."""
    x, y = data
    of = OnlineFalkon(KERN, x[:M], LAM, x=x[:1000], y=y[:1000], iters=ITERS,
                      chunk=512)
    of.refit()
    before = accumulate._ACC_SOLVE_TRACES
    for i in range(1000, 1800, 200):
        of.append(x[i:i + 200], y[i:i + 200])
        of.refit()
    assert accumulate._ACC_SOLVE_TRACES == before
    assert of.counters["refits"] == 5


# -- ingest fence ------------------------------------------------------------


def test_append_rejects_non_finite_batch_untouched(data):
    x, y = data
    of = OnlineFalkon(KERN, x[:M], LAM, x=x[:600], y=y[:600], chunk=256)
    h0, b0 = of._h, of._b
    bad = x[600:700].copy()
    bad[3, 1] = np.nan
    with pytest.raises(health.NonFiniteError):
        of.append(bad, y[600:700])
    assert bool(jnp.all(of._h == h0)) and bool(jnp.all(of._b == b0))
    assert of.store.shape[0] == 600  # store untouched too
    assert of.counters["rejected"] == 1 and of.counters["appends"] == 0
    with pytest.raises(health.NonFiniteError):
        of.append(x[600:700], np.full(100, np.inf, np.float32))
    assert of.counters["rejected"] == 2


def test_append_validates_shapes(data):
    x, y = data
    of = OnlineFalkon(KERN, x[:M], LAM, x=x[:600], y=y[:600])
    with pytest.raises(ValueError, match="append batch"):
        of.append(x[:10, :2], y[:10])
    with pytest.raises(ValueError, match="append targets"):
        of.append(x[:10], y[:9])


# -- center refresh ----------------------------------------------------------


def test_refresh_centers_inline(data):
    x, y = data
    of = OnlineFalkon(KERN, x[:M], LAM, x=x[:1500], y=y[:1500], iters=ITERS,
                      sampler=UniformSampler(m=M), chunk=512)
    of.refresh_centers(as_prng_key(3))
    model = of.refit()
    assert of.counters["refreshes"] == 1
    assert model.centers.shape[0] == M
    # refreshed model still fits the data it absorbed
    xt = jnp.asarray(x[:200])
    ref = falkon_fit(KERN, jnp.asarray(x[:1500]), jnp.asarray(y[:1500]),
                     model.centers, LAM, a_diag=model.a_diag, iters=ITERS)
    assert _pred_rel_err(ref, model, xt) < 2e-2


def test_background_refresh_absorbs_delta(data):
    """Rows appended while a background refresh runs are folded into the
    refreshed accumulators on join — nothing is lost in the handoff."""
    x, y = data
    of = OnlineFalkon(KERN, x[:M], LAM, x=x[:1200], y=y[:1200], iters=ITERS,
                      sampler=UniformSampler(m=M), chunk=512)
    of.refresh_centers(as_prng_key(5), background=True)
    of.append(x[1200:1800], y[1200:1800])  # the delta
    assert of.join_refresh()
    assert of.counters["refreshes"] == 1
    model = of.refit()
    ref = falkon_fit(KERN, jnp.asarray(x[:1800]), jnp.asarray(y[:1800]),
                     model.centers, LAM, a_diag=model.a_diag, iters=ITERS)
    assert _pred_rel_err(ref, model, jnp.asarray(x[:200])) < 2e-2
    assert not of.join_refresh()  # nothing left running


def test_refresh_needs_sampler(data):
    x, y = data
    of = OnlineFalkon(KERN, x[:M], LAM, x=x[:300], y=y[:300])
    with pytest.raises(ValueError, match="sampler"):
        of.refresh_centers(as_prng_key(0))


# -- ChunkStore growth -------------------------------------------------------


def test_chunkstore_append_grows_and_views():
    rng = np.random.default_rng(2)
    x0 = rng.normal(size=(100, 3)).astype(np.float32)
    y0 = rng.normal(size=(100,)).astype(np.float32)
    store = ChunkStore(x0, y0, chunk=64)
    xs, ys = [x0], [y0]
    for r in (1, 50, 300):
        xa = rng.normal(size=(r, 3)).astype(np.float32)
        ya = rng.normal(size=(r,)).astype(np.float32)
        assert store.append(xa, ya) == sum(a.shape[0] for a in xs) + r
        xs.append(xa)
        ys.append(ya)
    np.testing.assert_array_equal(store.x, np.concatenate(xs))
    np.testing.assert_array_equal(store.y, np.concatenate(ys))
    assert store.shape == (451, 3)
    assert store.n_chunks == 8
    assert store.x.flags["C_CONTIGUOUS"]


def test_chunkstore_append_validates():
    store = ChunkStore(np.zeros((4, 3), np.float32), np.zeros(4, np.float32))
    with pytest.raises(ValueError, match="append rows"):
        store.append(np.zeros((2, 5), np.float32), np.zeros(2, np.float32))
    with pytest.raises(ValueError, match="carries y"):
        store.append(np.zeros((2, 3), np.float32))
    xonly = ChunkStore(np.zeros((4, 3), np.float32))
    with pytest.raises(ValueError, match="has no y"):
        xonly.append(np.zeros((2, 3), np.float32), np.zeros(2, np.float32))


# -- resumable streamed fit (happy path; kill/resume lives in test_chaos) ----


def test_resumable_fit_matches_cold_and_checkpoints(data, tmp_path):
    x, y = data
    centers = jnp.asarray(x[:M])
    cold = falkon_fit(KERN, jnp.asarray(x), jnp.asarray(y), centers, LAM,
                      iters=ITERS)
    store = ChunkStore(x, y, chunk=512)
    key = as_prng_key(11)
    model = resumable_streamed_fit(KERN, store, centers=centers, lam=LAM,
                                   iters=ITERS, ckpt_dir=str(tmp_path),
                                   ckpt_every=2, key=key)
    assert _pred_rel_err(cold, model, jnp.asarray(x[:200])) < 1e-2
    # final barrier checkpointed: cursor == n_chunks, PRNG key round-trips
    step = latest_step(str(tmp_path))
    assert step == store.n_chunks
    extra = checkpoint_extra(str(tmp_path), step)
    assert extra["cursor"] == store.n_chunks and extra["rows"] == N
    _, tree = restore_checkpoint(
        str(tmp_path), {"h": jnp.zeros((M, M)), "b": jnp.zeros((M,)),
                        "key": np.zeros((2,), np.uint32)}, step=step)
    np.testing.assert_array_equal(np.asarray(tree["key"]),
                                  np.asarray(jax.random.key_data(key)))


def test_resumable_fit_refuses_config_mismatch(data, tmp_path):
    x, y = data
    centers = jnp.asarray(x[:M])
    store = ChunkStore(x, y, chunk=512)
    resumable_streamed_fit(KERN, store, centers=centers, lam=LAM,
                           iters=ITERS, ckpt_dir=str(tmp_path))
    for kwargs in ({"lam": LAM * 2}, {"iters": ITERS + 1},
                   {"centers": jnp.asarray(x[1:M + 1])}):
        with pytest.raises(ResumeMismatchError, match="refusing"):
            resumable_streamed_fit(
                KERN, store, centers=kwargs.get("centers", centers),
                lam=kwargs.get("lam", LAM), iters=kwargs.get("iters", ITERS),
                ckpt_dir=str(tmp_path))
    with pytest.raises(ResumeMismatchError):
        resumable_streamed_fit(
            KERN, ChunkStore(x, y, chunk=600), centers=centers, lam=LAM,
            iters=ITERS, ckpt_dir=str(tmp_path))  # different chunking
