"""AsyncKrrServer (serving/async_krr.py): happy-path parity with direct
predict, bounded-queue backpressure policies, deadlines, slot recycling,
and SLO-triggered degradation with hysteresis (virtual clock, no faults —
the fault-driven paths live in test_chaos.py)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import (AsyncKrrServer, FalkonRegressor, FitConfig,
                       ServeConfig)
from repro.core import falkon_fit, make_kernel
from repro.serving.async_krr import QueueFull, RequestStatus
from repro.testing import faults
from repro.testing.faults import VirtualClock

KERN = make_kernel("gaussian", sigma=1.5)


@pytest.fixture(scope="module")
def model():
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (400, 6))
    y = jnp.sin(2 * x[:, 0]) + 0.3 * x[:, 1] ** 2
    return falkon_fit(KERN, x, y, x[:48], 1e-3, iters=15, backend="jnp")


def _reqs(seeds_and_sizes):
    return [jax.random.normal(jax.random.PRNGKey(s), (r, 6))
            for s, r in seeds_and_sizes]


def test_results_match_direct_predict(model):
    srv = AsyncKrrServer(model, config=ServeConfig(max_wave=512, min_bucket=64))
    reqs = _reqs([(1, 3), (2, 17), (3, 64), (4, 100), (5, 1)])
    rids = [srv.submit(q) for q in reqs]
    srv.run_until_idle()
    for rid, q in zip(rids, reqs):
        assert srv.status(rid) == RequestStatus.DONE
        np.testing.assert_allclose(srv.result(rid), model.predict(q),
                                   rtol=1e-6, atol=1e-6)
    assert srv.stats["dispatches"] == 1  # 185 rows pack into one wave
    assert srv.stats["buckets"] == {256}


def test_multi_output_waves():
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (400, 6))
    y = jnp.sin(2 * x[:, 0])
    Y = jnp.stack([y, -y, jnp.cos(x[:, 2])], axis=1)
    m = falkon_fit(KERN, x, Y, x[:48], 1e-3, iters=12, backend="jnp")
    srv = AsyncKrrServer(m, config=ServeConfig(min_bucket=32))
    reqs = _reqs([(1, 5), (2, 40)])
    rids = [srv.submit(q) for q in reqs]
    srv.run_until_idle()
    for rid, q in zip(rids, reqs):
        assert srv.result(rid).shape == (q.shape[0], 3)
        np.testing.assert_allclose(srv.result(rid), m.predict(q),
                                   rtol=1e-6, atol=1e-6)


def test_estimator_unwrap_and_unfitted():
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (200, 4))
    est = FalkonRegressor(config=FitConfig(lam=1e-4, iters=10, backend="jnp"))
    with pytest.raises(ValueError, match="call .fit"):
        AsyncKrrServer(est)
    est.fit(x, jnp.sin(x[:, 0]))
    srv = AsyncKrrServer(est, config=ServeConfig(min_bucket=16))
    rid = srv.submit(x[:9])
    srv.run_until_idle()
    np.testing.assert_allclose(srv.result(rid), est.predict(x[:9]),
                               rtol=1e-6, atol=1e-6)


def test_submit_validation(model):
    srv = AsyncKrrServer(model, config=ServeConfig(max_wave=64))
    with pytest.raises(ValueError, match=r"\(r, 6\)"):
        srv.submit(jnp.zeros((5,)))
    with pytest.raises(ValueError, match=r"\(r, 6\)"):
        srv.submit(jnp.zeros((0, 6)))
    with pytest.raises(ValueError, match="non-finite"):
        srv.submit(jnp.full((4, 6), jnp.nan))
    with pytest.raises(ValueError, match="exceed max_wave"):
        srv.submit(jnp.zeros((65, 6)))


def test_backpressure_reject(model):
    srv = AsyncKrrServer(model, config=ServeConfig(max_queue_rows=20,
                                                   min_bucket=16))
    srv.submit(_reqs([(1, 12)])[0])
    with pytest.raises(QueueFull, match="cap 20"):
        srv.submit(_reqs([(2, 12)])[0])
    srv.run_until_idle()  # draining frees the queue again
    srv.submit(_reqs([(2, 12)])[0])
    srv.run_until_idle()


def test_backpressure_shed_oldest(model):
    srv = AsyncKrrServer(model, config=ServeConfig(
        max_queue_rows=20, overflow="shed_oldest", min_bucket=16))
    r1 = srv.submit(_reqs([(1, 12)])[0])
    r2 = srv.submit(_reqs([(2, 12)])[0])  # sheds r1 to admit r2
    assert srv.status(r1) == RequestStatus.SHED
    assert srv.result(r1) is None
    assert srv.stats["shed"] == 1
    srv.run_until_idle()
    assert srv.status(r2) == RequestStatus.DONE


def test_deadline_expiry_virtual_clock(model):
    clk = VirtualClock()
    srv = AsyncKrrServer(model, config=ServeConfig(deadline=1.0, min_bucket=16),
                         clock=clk)
    stale = srv.submit(_reqs([(1, 8)])[0])
    clk.advance(5.0)  # its deadline passes while queued
    fresh = srv.submit(_reqs([(2, 8)])[0])
    srv.run_until_idle()
    assert srv.status(stale) == RequestStatus.EXPIRED
    assert srv.status(fresh) == RequestStatus.DONE
    assert srv.stats["expired"] == 1
    # an explicit absolute deadline overrides the config default
    far = srv.submit(_reqs([(3, 8)])[0], deadline=clk() + 100.0)
    clk.advance(50.0)
    srv.run_until_idle()
    assert srv.status(far) == RequestStatus.DONE


def test_slot_recycling_under_load(model):
    """Many small requests against 2 in-flight slots: everything completes,
    waves respect max_wave, and the bucket set stays jit-cache bounded."""
    srv = AsyncKrrServer(model, config=ServeConfig(max_wave=64, min_bucket=16,
                                                   max_inflight=2))
    rids = [srv.submit(_reqs([(s, 1 + (s * 37) % 30)])[0]) for s in range(30)]
    srv.run_until_idle()
    assert all(srv.status(r) == RequestStatus.DONE for r in rids)
    assert srv.stats["dispatches"] >= 8  # 30 requests cannot fit one wave
    buckets = srv.stats["buckets"]
    assert all(b >= 16 and (b & (b - 1)) == 0 for b in buckets)
    assert len(buckets) <= 3  # 16..64: log2(max_wave/min_bucket)+1


def test_degradation_hysteresis_virtual_clock(model):
    """SLO breach flips to the fallback model; recovery waits for p99 to
    drop below recover_factor * slo (no flapping at the threshold)."""
    key = jax.random.PRNGKey(7)
    x = jax.random.normal(key, (100, 6))
    fallback = falkon_fit(KERN, x, jnp.sin(x[:, 0]), x[:16], 1e-2, iters=5,
                          backend="jnp")
    clk = VirtualClock()
    cfg = ServeConfig(min_bucket=16, slo=0.1, slo_window=4, recover_factor=0.5)
    srv = AsyncKrrServer(model, fallback_model=fallback, config=cfg, clock=clk)

    def serve_one(cost):
        rid = srv.submit(_reqs([(int(clk() * 100) % 97, 8)])[0])
        # the dispatch.latency hook advances the virtual clock *during* the
        # predict dispatch, so the wave's measured latency is `cost`
        with faults.fault("dispatch.latency", seconds=cost,
                          advance=clk.advance):
            srv.run_until_idle()
        return rid

    for _ in range(4):
        serve_one(0.5)  # p99 = 0.5 > slo
    assert srv.degraded
    serve_one(0.06)  # served by the fallback model while degraded
    assert srv.stats["degraded_waves"] >= 1
    # 0.06 < slo but NOT < 0.5 * slo: still degraded (hysteresis band)
    for _ in range(4):
        serve_one(0.06)
    assert srv.degraded
    for _ in range(4):
        serve_one(0.01)  # p99 sinks below 0.05 -> recover
    assert not srv.degraded
    done = serve_one(0.01)
    np.testing.assert_allclose(srv.result(done),
                               model.predict(srv._requests[done].x),
                               rtol=1e-6, atol=1e-6)


def test_degraded_results_come_from_fallback(model):
    key = jax.random.PRNGKey(7)
    x = jax.random.normal(key, (100, 6))
    fallback = falkon_fit(KERN, x, jnp.sin(x[:, 0]), x[:16], 1e-2, iters=5,
                          backend="jnp")
    clk = VirtualClock()
    srv = AsyncKrrServer(model, fallback_model=fallback,
                         config=ServeConfig(min_bucket=16, slo=0.1,
                                            slo_window=4), clock=clk)
    q = _reqs([(3, 8)])[0]
    rid = srv.submit(q)
    with faults.fault("dispatch.latency", seconds=1.0, advance=clk.advance):
        srv.run_until_idle()  # breaches SLO -> degraded for the NEXT wave
    assert srv.degraded
    rid2 = srv.submit(q)
    srv.run_until_idle()
    np.testing.assert_allclose(srv.result(rid2), fallback.predict(q),
                               rtol=1e-6, atol=1e-6)
    # primary-and-fallback differ, so this really was the fallback
    assert not np.allclose(np.asarray(srv.result(rid2)),
                           np.asarray(srv.result(rid)))


def test_config_validation():
    with pytest.raises(ValueError, match="overflow"):
        ServeConfig(overflow="drop_newest")
    with pytest.raises(ValueError, match="positive"):
        ServeConfig(max_wave=0)
    with pytest.raises(ValueError, match="recover_factor"):
        ServeConfig(recover_factor=0.0)


def test_fallback_dim_mismatch(model):
    key = jax.random.PRNGKey(1)
    x3 = jax.random.normal(key, (50, 3))
    bad = falkon_fit(make_kernel("gaussian", sigma=1.0), x3, x3[:, 0],
                     x3[:10], 1e-2, iters=3, backend="jnp")
    with pytest.raises(ValueError, match="feature dim"):
        AsyncKrrServer(model, fallback_model=bad)


# -- zero-downtime model swaps (DESIGN.md §11) --------------------------------


def _model2():
    key = jax.random.PRNGKey(42)
    x = jax.random.normal(key, (400, 6))
    y = jnp.cos(x[:, 0]) - 0.2 * x[:, 2]
    return falkon_fit(KERN, x, y, x[:32], 1e-3, iters=15, backend="jnp")


def test_swap_model_happy_path_and_provenance(model):
    clk = VirtualClock()
    srv = AsyncKrrServer(model, config=ServeConfig(min_bucket=16), clock=clk)
    assert srv.stats["model_version"] == 0 and srv.stats["last_swap"] is None
    q = _reqs([(1, 8)])[0]
    rid_old = srv.submit(q)
    srv.run_until_idle()
    m2 = _model2()
    clk.advance(5.0)
    assert srv.swap_model(m2)
    rid_new = srv.submit(q)
    srv.run_until_idle()
    # provenance in stats
    assert srv.stats["swaps"] == 1 and srv.stats["swaps_rejected"] == 0
    assert srv.stats["model_version"] == 1
    assert srv.stats["last_swap"] == 5.0  # model age = clock() - last_swap
    # each request tagged with the generation that actually served it
    assert srv._requests[rid_old].model_version == 0
    assert srv._requests[rid_new].model_version == 1
    np.testing.assert_allclose(srv.result(rid_old), model.predict(q),
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(srv.result(rid_new), m2.predict(q),
                               rtol=1e-6, atol=1e-6)


def test_swap_accepts_fitted_estimator(model):
    key = jax.random.PRNGKey(3)
    x = jax.random.normal(key, (300, 6))
    est = FalkonRegressor(kernel=KERN, config=FitConfig(lam=1e-3, iters=8))
    est.fit(x, jnp.sin(x[:, 0]))
    srv = AsyncKrrServer(model)
    assert srv.swap_model(est)
    assert srv.model is est.model_


def test_swap_rejects_poisoned_candidate(model):
    import dataclasses

    srv = AsyncKrrServer(model, config=ServeConfig(min_bucket=16))
    bad = dataclasses.replace(model, alpha=model.alpha.at[0].set(jnp.nan))
    assert not srv.swap_model(bad)
    assert srv.stats["swaps_rejected"] == 1 and srv.stats["swaps"] == 0
    assert srv.stats["model_version"] == 0
    assert srv.model is model  # incumbent keeps serving
    q = _reqs([(2, 8)])[0]
    rid = srv.submit(q)
    srv.run_until_idle()
    assert srv.status(rid) == RequestStatus.DONE
    from repro.core import health
    assert health.events("swap_rejected")


def test_swap_probe_uses_probe_batch(model):
    """A candidate that is finite on its centers but explodes on the probe
    batch is caught by the explicit probe_x fence."""
    srv = AsyncKrrServer(model)
    # alpha scaled to overflow fp32 on any probe: predictions go inf
    import dataclasses
    bad = dataclasses.replace(model, alpha=model.alpha * jnp.float32(1e38))
    assert not srv.swap_model(bad, probe_x=_reqs([(5, 4)])[0])
    assert srv.stats["swaps_rejected"] == 1


def test_swap_replaces_fallback_in_same_call(model):
    m2 = _model2()
    fb = _model2()
    srv = AsyncKrrServer(model)
    assert srv.swap_model(m2, fallback_model=fb)
    assert srv.fallback_model is fb
    assert srv.swap_model(model, fallback_model=None)  # clears it
    assert srv.fallback_model is None
    assert srv.swap_model(m2)  # omitted = kept (still None)
    assert srv.fallback_model is None


def test_swap_validation_errors_propagate(model):
    srv = AsyncKrrServer(model)
    with pytest.raises(ValueError, match="no fitted model"):
        srv.swap_model(FalkonRegressor(kernel=KERN))
    key = jax.random.PRNGKey(1)
    x3 = jax.random.normal(key, (50, 3))
    wrong_d = falkon_fit(make_kernel("gaussian", sigma=1.0), x3, x3[:, 0],
                         x3[:10], 1e-2, iters=3, backend="jnp")
    with pytest.raises(ValueError, match="feature dim"):
        srv.swap_model(wrong_d)
    assert srv.stats["swaps"] == 0  # neither counted as swap activity
    assert srv.stats["swaps_rejected"] == 0


def test_krr_server_swap_provenance(model):
    from repro.serving import KrrServer

    clk = VirtualClock()
    ks = KrrServer(model, clock=clk)
    clk.advance(2.0)
    assert ks.swap_model(_model2())
    assert ks.stats["swaps"] == 1 and ks.stats["model_version"] == 1
    assert ks.stats["last_swap"] == 2.0
    import dataclasses
    bad = dataclasses.replace(model, alpha=model.alpha.at[0].set(jnp.inf))
    assert not ks.swap_model(bad)
    assert ks.stats["swaps_rejected"] == 1
