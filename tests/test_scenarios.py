"""Scenario-layer statistical gates (ISSUE PR 9).

Three correctness gates on the new ``repro.api`` scenario surface, each
pinning a statistical identity rather than an implementation detail:

  * predictive variance — ``predictive_variance`` / ``predict(return_std=
    True)`` must match the exact GP posterior variance when the Nystrom
    approximation is exact (centers = all training points, A = I);
  * ``FalkonClassifier`` — the one multi-RHS solve must reproduce k looped
    per-class KRR solves (same centers, same preconditioner) on every
    backend;
  * exact row-exclusion CV — ``KFoldSweep`` scores must equal naive
    per-fold refits on ``x[train], y[train]`` to 1e-6.

Plus property-based distribution tests for ``core/sampling.py`` through
``hypothesis`` (the real library in CI; the deterministic offline stub in
the container — both run the same assertions). ``derandomize=True`` keeps
CI replay-stable: no flaky example sequences.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.api import (FalkonClassifier, FalkonRegressor, FitConfig,
                       KFoldSweep, UniformSampler)
from repro.core import falkon_fit, make_kernel
from repro.core.nystrom import nystrom_krr
from repro.core.sampling import categorical, gumbel_topk

BACKENDS = ["jnp", "pallas", "sharded"]
VAR_FAMILIES = ["gaussian", "laplacian", "matern32"]


# ---------------------------------------------------------------------------
# Gate 1: Nystrom predictive variance vs the exact GP posterior.
# ---------------------------------------------------------------------------


def _gp_problem(n=120, d=4, seed=0):
    key = jax.random.PRNGKey(seed)
    kx, kt, ky = jax.random.split(key, 3)
    x = jax.random.normal(kx, (n, d))
    xt = jax.random.normal(kt, (40, d)) * 1.5
    y = jnp.sin(2 * x[:, 0]) + 0.1 * jax.random.normal(ky, (n,))
    return x, y, xt


@pytest.mark.parametrize("kind", VAR_FAMILIES)
def test_predictive_variance_matches_exact_gp_posterior(kind):
    """With centers = all training points and A = I the Nystrom posterior
    IS the exact GP posterior: var(x) = k(x,x) - k_xn (K_nn + lam n I)^{-1}
    k_nx. The seam's fused-RLS route must reproduce it to 5e-2 relative
    (measured ~1e-4; the gate leaves fp32 headroom)."""
    kern = make_kernel(kind, sigma=1.8)
    x, y, xt = _gp_problem()
    n, lam = x.shape[0], 1e-3
    model = nystrom_krr(kern, x, y, x, lam, backend="jnp")
    got = model.predictive_variance(xt)

    knn = kern.gram(x)
    kxn = kern.cross(xt, x)
    h = knn + lam * n * jnp.eye(n, dtype=knn.dtype)
    exact = kern.diag(xt) - jnp.sum(kxn * jnp.linalg.solve(h, kxn.T).T, axis=1)

    assert got.shape == (xt.shape[0],)
    assert bool(jnp.all(got >= 0.0))
    rel = float(jnp.max(jnp.abs(got - exact))
                / jnp.maximum(jnp.max(jnp.abs(exact)), 1e-30))
    assert rel < 5e-2, (kind, rel)


def test_predictive_variance_shrinks_at_training_points():
    """Posterior variance at training inputs must be far below the prior
    k(x,x) and far below the variance at out-of-distribution points."""
    kern = make_kernel("gaussian", sigma=1.5)
    x, y, _ = _gp_problem()
    far = jnp.ones((10, x.shape[1])) * 40.0  # far outside the data cloud
    model = nystrom_krr(kern, x, y, x, 1e-4, backend="jnp")
    v_train = model.predictive_variance(x)
    v_far = model.predictive_variance(far)
    assert float(jnp.max(v_train)) < 0.1 * float(jnp.min(v_far))
    # far from every center the posterior reverts to the prior k(x,x) = 1
    np.testing.assert_allclose(np.asarray(v_far), 1.0, rtol=1e-3)


@pytest.mark.parametrize("name", BACKENDS)
def test_predictive_variance_backend_parity(name):
    """The variance rides ``Backend.rls_scores``; every backend must agree
    with the jnp seam at the documented cross-backend tolerance."""
    kern = make_kernel("gaussian", sigma=1.5)
    x, y, xt = _gp_problem(n=200)
    est = FalkonRegressor(kernel=kern, sampler=UniformSampler(m=48),
                          config=FitConfig(lam=1e-4, iters=10, backend="jnp"))
    est.fit(x, y)
    ref = np.asarray(est.predictive_variance(xt))
    got = np.asarray(est.model_.predictive_variance(xt, backend=name))
    # the repo-wide cross-backend contract: 1e-4 *scale-relative* (variances
    # near zero at training points make per-element rtol meaningless)
    scale = max(float(np.max(np.abs(ref))), 1e-30)
    assert float(np.max(np.abs(got - ref))) / scale < 2e-4, name


def test_predict_return_std_surface():
    """predict(return_std=True) returns (pred, sqrt(variance)) with shared
    std across output columns; unfitted estimators raise."""
    kern = make_kernel("gaussian", sigma=1.5)
    x, y, xt = _gp_problem()
    est = FalkonRegressor(kernel=kern, sampler=UniformSampler(m=40),
                          config=FitConfig(lam=1e-4, iters=10, backend="jnp"))
    with pytest.raises(RuntimeError, match="not fitted"):
        est.predictive_variance(xt)
    est.fit(x, jnp.stack([y, -y], axis=1))
    pred, std = est.predict(xt, return_std=True)
    assert pred.shape == (xt.shape[0], 2) and std.shape == (xt.shape[0],)
    np.testing.assert_allclose(np.asarray(std),
                               np.sqrt(np.asarray(est.predictive_variance(xt))),
                               rtol=1e-6)


def test_model_variance_requires_fit_metadata():
    """Hand-built FalkonModels without lam/n_train metadata refuse to guess."""
    from repro.core.falkon import FalkonModel
    from repro.core.gram import resolve_backend

    kern = make_kernel("gaussian", sigma=1.0)
    z = jnp.zeros((4, 2))
    model = FalkonModel(centers=z, alpha=jnp.zeros((4,)), kernel=kern,
                        backend=resolve_backend("jnp"))
    with pytest.raises(ValueError, match="fit metadata"):
        model.predictive_variance(z)


# ---------------------------------------------------------------------------
# Gate 2: FalkonClassifier vs k looped per-class KRR solves.
# ---------------------------------------------------------------------------


def _class_problem(n=360, d=5, classes=3, seed=0):
    key = jax.random.PRNGKey(seed)
    kc, kx = jax.random.split(key)
    means = jax.random.normal(kc, (classes, d)) * 3.0
    labels = np.arange(n) % classes
    x = means[labels] + jax.random.normal(kx, (n, d))
    return x, labels


@pytest.mark.parametrize("name", BACKENDS)
def test_classifier_matches_looped_per_class_krr(name):
    """The one multi-RHS panel solve must reproduce k independent per-class
    FALKON solves on the same centers: identical margins (to CG/fp32
    tolerance) and identical argmax labels."""
    x, labels = _class_problem()
    kern = make_kernel("gaussian", sigma=2.0)
    clf = FalkonClassifier(kernel=kern, sampler=UniformSampler(m=64),
                           config=FitConfig(lam=1e-4, iters=30, backend=name))
    clf.fit(x, labels)
    margins = clf.decision_function(x)
    assert margins.shape == (x.shape[0], 3)

    cs = clf.center_set_
    m = int(cs.count)
    centers, a_diag = x[cs.idx[:m]], cs.weight[:m]
    for c in range(3):
        target = jnp.where(jnp.asarray(labels) == c, 1.0, -1.0)
        col = falkon_fit(kern, x, target, centers, 1e-4, a_diag=a_diag,
                         iters=30, backend=name)
        ref = col.predict(x)
        rel = float(jnp.linalg.norm(margins[:, c] - ref)
                    / jnp.maximum(jnp.linalg.norm(ref), 1e-30))
        assert rel < 1e-3, (name, c, rel)
    looped = np.argmax(np.stack(
        [np.asarray(falkon_fit(kern, x, jnp.where(jnp.asarray(labels) == c, 1.0, -1.0),
                               centers, 1e-4, a_diag=a_diag, iters=30,
                               backend=name).predict(x)) for c in range(3)],
        axis=1), axis=1)
    np.testing.assert_array_equal(np.asarray(clf.predict(x)), looped)


def test_classifier_api_surface():
    """Labels round-trip through classes_ (string labels included),
    predict_proba rows sum to 1 and rank like the margins, score is
    accuracy, and easy clustered data is nearly separable."""
    x, labels = _class_problem()
    names = np.array(["ant", "bee", "cat"])[labels]
    clf = FalkonClassifier(kernel="gaussian", sigma=2.0,
                           sampler=UniformSampler(m=64),
                           config=FitConfig(lam=1e-4, iters=15, backend="jnp"))
    clf.fit(x, names)
    np.testing.assert_array_equal(clf.classes_, np.array(["ant", "bee", "cat"]))
    pred = clf.predict(x)
    assert pred.dtype == clf.classes_.dtype
    acc = clf.score(x, names)
    assert acc > 0.95, acc
    proba = clf.predict_proba(x)
    np.testing.assert_allclose(np.asarray(jnp.sum(proba, axis=1)), 1.0,
                               rtol=1e-5)
    np.testing.assert_array_equal(np.asarray(jnp.argmax(proba, axis=1)),
                                  np.asarray(jnp.argmax(clf.decision_function(x),
                                                        axis=1)))
    labels2, std = clf.predict(x, return_std=True)
    np.testing.assert_array_equal(labels2, pred)
    assert std.shape == (x.shape[0],) and bool(jnp.all(std >= 0.0))


def test_classifier_binary_keeps_both_margins():
    x, labels = _class_problem(classes=2)
    clf = FalkonClassifier(kernel="gaussian", sigma=2.0,
                           sampler=UniformSampler(m=48),
                           config=FitConfig(lam=1e-4, iters=12, backend="jnp"))
    clf.fit(x, labels)
    assert clf.decision_function(x).shape == (x.shape[0], 2)
    assert clf.score(x, labels) > 0.95


def test_classifier_validates_inputs():
    x, labels = _class_problem(n=60)
    clf = FalkonClassifier(sampler=UniformSampler(m=16),
                           config=FitConfig(lam=1e-3, iters=5, backend="jnp"))
    with pytest.raises(ValueError, match=r"\(n,\) labels"):
        clf.fit(x, np.stack([labels, labels], axis=1))
    with pytest.raises(ValueError, match="2 classes"):
        clf.fit(x, np.zeros(x.shape[0], np.int32))
    with pytest.raises(ValueError, match="callback"):
        clf.fit(x, labels, callback=lambda i, m: None)


def test_classifier_warm_start_rides_fused_cache():
    """Warm-start refits keep the centers and the k-bucketed executable:
    zero retraces on the second fit."""
    from repro.core import falkon as falkon_mod

    x, labels = _class_problem(n=280)
    clf = FalkonClassifier(kernel="gaussian", sigma=2.0,
                           sampler=UniformSampler(m=56), warm_start=True,
                           config=FitConfig(lam=1e-4, iters=11, backend="jnp"))
    clf.fit(x, labels)
    centers = clf.centers_
    t0 = falkon_mod._FUSED_FIT_TRACES
    clf.config = FitConfig(lam=1e-3, iters=11, backend="jnp")
    clf.fit(x, labels)  # lam is traced; same shapes -> cache hit
    assert falkon_mod._FUSED_FIT_TRACES == t0
    assert clf.centers_ is centers


# ---------------------------------------------------------------------------
# Gate 3: exact row-exclusion CV vs naive per-fold refits (1e-6).
# ---------------------------------------------------------------------------


def test_exact_kfold_matches_per_fold_refits_to_1e6():
    """Column f of the sweep's panel solve must land on the SAME linear
    system as a from-scratch ``falkon_fit(x[train], y[train], ...)`` refit
    (same centers, fold-local n in the regularization) — scores agree to
    1e-6, not the old fold-masked-RHS approximation's 1e-3."""
    from repro.api.sweep import fold_ids

    key = jax.random.PRNGKey(2)
    x = jax.random.normal(key, (420, 6))
    y = (jnp.sin(2 * x[:, 0]) + 0.3 * x[:, 1] ** 2
         + 0.05 * jax.random.normal(jax.random.PRNGKey(3), (420,)))
    # lam >= 5e-3 keeps both solves comfortably inside the fp32 noise floor
    # (at lam=1e-3 the floor itself is ~2e-6 — conditioning, not semantics)
    folds, lams, iters = 4, (1e-2, 5e-3), 30
    sweep = KFoldSweep(kernel="gaussian", sigma=1.5,
                       sampler=UniformSampler(m=64), lams=lams, folds=folds,
                       iters=iters, backend="jnp", seed=0)
    res = sweep.run(x, y)

    kern = make_kernel("gaussian", sigma=1.5)
    k_sample, k_fold = jax.random.split(jax.random.PRNGKey(0))
    fid = fold_ids(k_fold, x.shape[0], folds)
    cs = UniformSampler(m=64).sample(k_sample, x, kern, backend="jnp")
    m = int(cs.count)
    centers, a_diag = x[cs.idx[:m]], cs.weight[:m]
    for li, lam in enumerate(lams):
        for f in range(folds):
            train = np.asarray(fid != f)
            model = falkon_fit(kern, x[train], y[train], centers, lam,
                               a_diag=a_diag, iters=iters, backend="jnp")
            held = np.asarray(fid == f)
            mse = float(jnp.mean((model.predict(x[held]) - y[held]) ** 2))
            got = float(res.scores[li, f])
            assert abs(mse - got) < 1e-6 * max(1.0, abs(mse)), (li, f, mse, got)


# ---------------------------------------------------------------------------
# Property-based sampler-distribution tests (hypothesis; stub offline).
# ---------------------------------------------------------------------------

_CHI2_99 = {  # chi-square 0.99 critical values by degrees of freedom
    3: 11.34, 4: 13.28, 5: 15.09, 6: 16.81, 7: 18.48, 9: 21.67, 11: 24.72,
    15: 30.58, 19: 36.19, 23: 41.64, 31: 52.19,
}


def _chi2_bound(df: int) -> float:
    """0.99 critical value, padded 1.5x so a correct sampler's one-in-100
    tail cannot flake CI (draws are derandomized anyway — the pad guards
    the stub/real-hypothesis example-sequence difference, not randomness)."""
    crit = _CHI2_99.get(df, df + 2.33 * (2 * df) ** 0.5)
    return 1.5 * crit


@settings(max_examples=12, deadline=None, derandomize=True)
@given(seed=st.integers(min_value=0, max_value=2**31 - 1),
       r=st.integers(min_value=4, max_value=24))
def test_categorical_frequencies_match_choice(seed, r):
    """Inverse-CDF draws follow p = w / sum(w): observed counts of 8000
    draws sit within a (padded) chi-square bound of the expected counts —
    the same bound np.random.choice itself satisfies — and zero-weight
    (padded) slots are never selected."""
    rng = np.random.default_rng(seed)
    w = rng.uniform(0.1, 3.0, size=r).astype(np.float32)
    w[rng.integers(0, r)] = 0.0  # one padded slot
    m = 8000
    idx = np.asarray(categorical(jax.random.PRNGKey(seed), jnp.asarray(w), m))
    assert idx.shape == (m,) and idx.min() >= 0 and idx.max() < r
    p = w / w.sum()
    counts = np.bincount(idx, minlength=r)
    assert counts[w == 0.0].sum() == 0
    live = p > 0
    expected = m * p[live]
    stat = float(np.sum((counts[live] - expected) ** 2 / expected))
    df = int(live.sum()) - 1
    assert stat < _chi2_bound(df), (seed, r, stat, df)
    # reference draw: np.random.choice under the same p passes the same gate
    ref = np.bincount(rng.choice(r, size=m, p=p), minlength=r)
    ref_stat = float(np.sum((ref[live] - expected) ** 2 / expected))
    assert ref_stat < _chi2_bound(df), (seed, r, ref_stat, df)


@settings(max_examples=12, deadline=None, derandomize=True)
@given(seed=st.integers(min_value=0, max_value=2**31 - 1),
       r=st.integers(min_value=6, max_value=32),
       k=st.integers(min_value=1, max_value=6))
def test_gumbel_topk_is_without_replacement(seed, r, k):
    """Every draw returns k DISTINCT in-range indices, and zero-weight slots
    are only used when fewer than k valid slots exist."""
    k = min(k, r - 2)
    rng = np.random.default_rng(seed)
    w = rng.uniform(0.1, 3.0, size=r).astype(np.float32)
    dead = rng.integers(0, r)
    w[dead] = 0.0
    idx = np.asarray(gumbel_topk(jax.random.PRNGKey(seed), jnp.asarray(w), k))
    assert idx.shape == (k,)
    assert len(set(idx.tolist())) == k  # without replacement
    assert idx.min() >= 0 and idx.max() < r
    assert dead not in idx  # k <= valid slots, so the dead slot never drawn


@settings(max_examples=6, deadline=None, derandomize=True)
@given(seed=st.integers(min_value=0, max_value=10_000))
def test_gumbel_topk_uniform_scores_are_permutation_distributed(seed):
    """On uniform weights the top-k is a uniform random k-subset in uniform
    random order: over many keys, each index lands in each of the k output
    positions equally often (chi-square on the position-0 and position-(k-1)
    marginals)."""
    r, k, trials = 8, 3, 4000
    w = jnp.ones((r,))
    draws = np.stack([
        np.asarray(gumbel_topk(jax.random.PRNGKey(seed * 100_003 + t), w, k))
        for t in range(trials)])
    for pos in (0, k - 1):
        counts = np.bincount(draws[:, pos], minlength=r)
        expected = trials / r
        stat = float(np.sum((counts - expected) ** 2 / expected))
        assert stat < _chi2_bound(r - 1), (seed, pos, stat)
    # distinctness across the whole panel
    assert all(len(set(row.tolist())) == k for row in draws)
