"""BLESS / BLESS-R: Thm. 1-style accuracy and size bounds, ladder
properties, baselines sanity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (bless, bless_r, exact_rls, lam_ladder, make_kernel,
                        recursive_rls, squeak, theory_constants, two_pass)
from repro.core.leverage import approx_rls_all

KERN = make_kernel("gaussian", sigma=2.0)
LAM = 1e-3


def test_lam_ladder():
    lams = lam_ladder(1e-3, 1.0, 2.0)
    assert lams[-1] == 1e-3
    assert all(a > b for a, b in zip(lams, lams[1:]))
    assert len(lams) == 10  # ceil(log2(1000))


def test_theory_constants_reproduce_thm1():
    q1, q2 = theory_constants(t=1.0, q=2.0, n=1000, h=10, delta=0.1)
    assert q2 >= 12 * 2 * 9 * 2 * np.log(12 * 10 * 1000 / 0.1) - 1
    assert q1 == pytest.approx(5 * q2 / (2 * 2))


@pytest.mark.parametrize("algo", [bless, bless_r])
def test_multiplicative_accuracy(clustered_data, algo):
    """Thm. 1(a): scores within a constant multiplicative band of exact
    (practical constants -> a loose 3x band checked at the 2nd/98th pct)."""
    x = clustered_data
    ell = exact_rls(KERN, x, LAM)
    kw = dict(q2=4.0) if algo is bless_r else dict(q1=4.0, q2=4.0)
    res = algo(jax.random.PRNGKey(0), x, KERN, LAM, **kw)
    racc = np.asarray(res.scores(KERN, x) / ell)
    assert 0.8 < racc.mean() < 1.4
    assert np.quantile(racc, 0.02) > 1 / 3.0
    assert np.quantile(racc, 0.98) < 3.0


def test_thm1b_size_bound(clustered_data):
    """|J_h| stays O(q2 d_eff(lam_h)) along the whole path."""
    x = clustered_data
    q2 = 3.0
    res = bless(jax.random.PRNGKey(1), x, KERN, LAM, q1=3.0, q2=q2)
    for lvl in res.levels[2:]:
        deff_h = float(jnp.sum(exact_rls(KERN, x, lvl.lam)))
        assert lvl.m_h <= q2 * max(10 * 2.0, 3 * 2.0 * deff_h) + 8, (
            lvl.lam, lvl.m_h, deff_h)


def test_path_accuracy(clustered_data):
    """The 'whole path at once' claim: intermediate levels are accurate at
    their own lam_h, not just the last one."""
    x = clustered_data
    res = bless(jax.random.PRNGKey(2), x, KERN, LAM, q1=4.0, q2=4.0)
    for lvl in (res.levels[-3], res.levels[-1]):
        ell_h = exact_rls(KERN, x, lvl.lam)
        s = approx_rls_all(KERN, x, lvl.centers, jnp.asarray(lvl.lam))
        racc = np.asarray(s / ell_h)
        assert 0.6 < np.median(racc) < 1.8, lvl.lam


def test_bless_deterministic_given_key(clustered_data):
    r1 = bless(jax.random.PRNGKey(3), clustered_data, KERN, LAM)
    r2 = bless(jax.random.PRNGKey(3), clustered_data, KERN, LAM)
    assert r1.final.m_h == r2.final.m_h
    assert bool(jnp.all(r1.final.centers.idx == r2.final.centers.idx))


@pytest.mark.parametrize("baseline", [two_pass, recursive_rls, squeak])
def test_baselines_produce_usable_scores(clustered_data, baseline):
    x = clustered_data
    ell = exact_rls(KERN, x, LAM)
    kw = {"m2": 300} if baseline is two_pass else {"m_cap": 400}
    cs = baseline(jax.random.PRNGKey(4), x, KERN, LAM, **kw)
    s = approx_rls_all(KERN, x, cs, jnp.asarray(LAM))
    racc = np.asarray(s / ell)
    assert 0.5 < np.median(racc) < 2.0
