"""Health fences (core/health.py): the escalating-jitter Cholesky ladder
succeeds or raises — never a silent NaN — across indefinite, rank-deficient
and fp32-borderline inputs; SolveDiagnostics classifies CG trajectories;
falkon_fit surfaces diagnostics and the opt-in finite-output fence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import falkon_fit, make_kernel
from repro.core import health
from repro.core.nystrom import exact_krr, nystrom_krr

KERN = make_kernel("gaussian", sigma=1.5)


@pytest.fixture(autouse=True)
def _clean_events():
    health.clear_events()
    yield
    health.clear_events()


def _spd(n, seed=0, dtype=jnp.float32):
    a = jax.random.normal(jax.random.PRNGKey(seed), (n, n), dtype=dtype)
    return a @ a.T + n * jnp.eye(n, dtype=dtype)


# -- the ladder itself -------------------------------------------------------


def test_ladder_level0_on_well_conditioned():
    chol, level = health.safe_cholesky(_spd(32), what="well-conditioned")
    assert level == 0
    assert bool(jnp.all(jnp.isfinite(chol)))
    assert health.events("jitter_escalation") == []  # no escalation recorded


def test_ladder_recovers_rank_deficient():
    """A rank-1 Gram matrix (plain Cholesky -> NaN rows) is rescued by the
    ladder: finite factor, level reported, never silent NaN."""
    v = jnp.linspace(1.0, 2.0, 24)
    a = jnp.outer(v, v)  # rank 1, PSD, singular
    assert bool(jnp.any(jnp.isnan(jnp.linalg.cholesky(a))))  # ladder needed
    chol, level = health.safe_cholesky(a, what="rank-1")
    assert bool(jnp.all(jnp.isfinite(chol)))
    assert 0 <= level < health.JITTER_LEVELS


def test_ladder_escalates_on_slight_indefiniteness():
    """Subtracting ~5e-4 x mean-diag pushes the matrix just indefinite: the
    base 1e-6-scale jitter cannot fix it, a mid-ladder level can — the
    reported level must be > 0 and the escalation recorded."""
    v = jnp.linspace(1.0, 2.0, 24)
    a = jnp.outer(v, v)
    md = float(jnp.mean(jnp.diagonal(a)))
    a = a - 5e-4 * md * jnp.eye(24)
    chol, level = health.safe_cholesky(a, what="slightly-indefinite")
    assert bool(jnp.all(jnp.isfinite(chol)))
    assert 0 < level < health.JITTER_LEVELS
    evts = health.events("jitter_escalation")
    assert len(evts) == 1 and evts[0]["level"] == level


def test_ladder_recovers_fp32_borderline():
    """Near-rank-deficient fp32 kernel matrix (huge bandwidth => all entries
    ~1): the ladder must produce a finite factor, never NaN."""
    x = jax.random.normal(jax.random.PRNGKey(1), (64, 3))
    k = make_kernel("gaussian", sigma=100.0).gram(x)  # numerically ~ ones
    chol, level = health.safe_cholesky(k, what="fp32-borderline")
    assert bool(jnp.all(jnp.isfinite(chol)))
    assert level < health.JITTER_LEVELS


def test_ladder_raises_on_hopeless_indefinite():
    """A negative-definite matrix has a ~0-or-negative trace scale, so no
    ladder level can fix it: the fence must raise, not return NaN."""
    a = -jnp.eye(16)
    with pytest.raises(health.FactorizationError, match="not numerically PSD"):
        health.safe_cholesky(a, what="negative-definite")
    assert health.events("factorization_failure")


def test_ladder_is_jit_safe():
    """chol_with_jitter_ladder must trace (it is what _chol_with_jitter and
    the fused-fit preconditioner run under jit)."""
    chol, level = jax.jit(health.chol_with_jitter_ladder)(_spd(16, seed=3))
    assert bool(jnp.all(jnp.isfinite(chol)))
    assert int(level) == 0


def test_psd_solve_still_exact_through_ladder():
    """The leverage-score _psd_solve path (now routed through the ladder)
    keeps its accuracy on healthy matrices — level 0 adds ~1e-6-scale
    jitter only."""
    from repro.core.leverage import _psd_solve
    a = _spd(24, seed=5)
    b = jax.random.normal(jax.random.PRNGKey(6), (24, 4))
    np.testing.assert_allclose(np.asarray(a @ _psd_solve(a, b)), np.asarray(b),
                               rtol=1e-3, atol=1e-3)


# -- finite-output fence -----------------------------------------------------


def test_check_finite_passthrough_and_raise():
    x = jnp.arange(6.0)
    assert health.check_finite(x, "ok") is x
    bad = x.at[2].set(jnp.nan).at[4].set(jnp.inf)
    with pytest.raises(health.NonFiniteError, match="2 non-finite"):
        health.check_finite(bad, "poisoned")
    assert health.events("non_finite")[0]["bad"] == 2


# -- CG trajectory diagnostics ----------------------------------------------


def test_diagnostics_converged():
    r = jnp.asarray([1.0, 1e-3, 1e-6, 1e-10])
    d = health.SolveDiagnostics(r)
    assert d.converged and not d.diverged and not d.stalled
    assert "converged" in d.summary()


def test_diagnostics_diverged():
    r = jnp.asarray([1.0, 10.0, 1e4])
    d = health.SolveDiagnostics(r)
    assert d.diverged and not d.converged
    assert "diverged" in d.summary()


def test_diagnostics_stalled():
    # fast early drop, then flat for the whole second half, far from tol
    r = jnp.asarray([1.0, 1e-2, 1e-3, 1e-3, 1e-3, 1e-3, 1e-3, 1e-3])
    d = health.SolveDiagnostics(r)
    assert d.stalled and not d.converged and not d.diverged
    assert "stalled" in d.summary()


def test_diagnostics_multi_rhs_worst_column_governs():
    good = jnp.asarray([1.0, 1e-5, 1e-10])
    flat = jnp.asarray([1.0, 0.9, 0.8])
    d = health.SolveDiagnostics(jnp.stack([good, flat], axis=1))
    assert not d.converged  # column 2 is nowhere near
    assert d.reduction.shape == (2,)
    assert d.reduction[0] < health.CONVERGED_REL <= d.reduction[1]


# -- solver integration ------------------------------------------------------


@pytest.fixture(scope="module")
def xy():
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (300, 4))
    y = jnp.sin(2 * x[:, 0]) + 0.1 * x[:, 1]
    return x, y


def test_falkon_fit_attaches_diagnostics(xy):
    x, y = xy
    m = falkon_fit(KERN, x, y, x[:40], 1e-4, iters=20, backend="jnp")
    assert m.diagnostics is not None
    assert m.diagnostics.residuals.shape == (21,)
    # a healthy small problem makes real progress and never diverges
    assert not m.diagnostics.diverged
    assert float(m.diagnostics.reduction.max()) < 1e-2


def test_falkon_fit_host_path_diagnostics(xy):
    """The callback (host-CG) path records the same trajectory shape."""
    x, y = xy
    seen = []
    m = falkon_fit(KERN, x, y, x[:40], 1e-4, iters=10, backend="jnp",
                   callback=lambda i, model: seen.append(i))
    assert len(seen) == 10
    assert m.diagnostics is not None and m.diagnostics.residuals.shape == (11,)
    assert not m.diagnostics.diverged


def test_falkon_fit_finite_fence_opt_in(xy):
    """check_finite=True turns a NaN-poisoned solve into a NonFiniteError
    instead of silently returning NaN alpha."""
    x, y = xy
    y_bad = y.at[0].set(jnp.nan)
    m = falkon_fit(KERN, x, y_bad, x[:40], 1e-4, iters=5, backend="jnp")
    assert bool(jnp.any(jnp.isnan(m.alpha)))  # default: unfenced hot path
    with pytest.raises(health.NonFiniteError):
        falkon_fit(KERN, x, y_bad, x[:40], 1e-4, iters=5, backend="jnp",
                   check_finite=True)


def test_direct_solvers_always_fenced(xy):
    """nystrom_krr / exact_krr are eager oracles: their fences are always
    armed, so poisoned targets raise rather than fit a NaN model."""
    x, y = xy
    y_bad = y.at[3].set(jnp.inf)
    with pytest.raises(health.NonFiniteError):
        nystrom_krr(KERN, x, y_bad, x[:30], 1e-4, backend="jnp")
    with pytest.raises(health.NonFiniteError):
        exact_krr(KERN, x[:60], y_bad[:60], 1e-4, backend="jnp")


def test_estimator_threads_check_finite(xy):
    from repro.api import FalkonRegressor, FitConfig
    x, y = xy
    y_bad = y.at[0].set(jnp.nan)
    est = FalkonRegressor(kernel=KERN,
                          config=FitConfig(lam=1e-4, iters=5, backend="jnp",
                                           check_finite=True))
    with pytest.raises(health.NonFiniteError):
        est.fit(x, y_bad)


def test_event_log_bounded_and_filterable():
    for i in range(600):
        health.record_event("spam", i=i)
    assert len(health.events()) == 512  # deque maxlen
    health.record_event("other")
    assert len(health.events("other")) == 1
    health.clear_events()
    assert health.events() == []
