"""KrrServer (serving/krr.py): batched predictions match the direct path
exactly, waves respect the row budget, buckets are pow2 and bounded."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import falkon_fit, make_kernel
from repro.serving import KrrServer, pow2_bucket

KERN = make_kernel("gaussian", sigma=1.5)


@pytest.fixture(scope="module")
def model():
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (400, 6))
    y = jnp.sin(2 * x[:, 0]) + 0.3 * x[:, 1] ** 2
    return falkon_fit(KERN, x, y, x[:48], 1e-3, iters=15, backend="jnp")


def _requests(seeds_and_sizes):
    return [jax.random.normal(jax.random.PRNGKey(s), (r, 6))
            for s, r in seeds_and_sizes]


def test_pow2_bucket():
    assert pow2_bucket(1, 64) == 64
    assert pow2_bucket(64, 64) == 64
    assert pow2_bucket(65, 64) == 128
    assert pow2_bucket(1000, 64) == 1024
    assert pow2_bucket(1024, 64) == 1024


def test_batched_matches_direct(model):
    server = KrrServer(model, max_wave=512, min_bucket=64)
    reqs = _requests([(1, 3), (2, 17), (3, 64), (4, 100), (5, 1)])
    rids = [server.submit(q) for q in reqs]
    out = server.flush()
    assert server.pending_rows == 0
    for rid, q in zip(rids, reqs):
        np.testing.assert_allclose(out[rid], model.predict(q), rtol=1e-6, atol=1e-6)
    # all five requests fit one wave (185 rows <= 512)
    assert server.stats["dispatches"] == 1
    assert server.stats["buckets"] == {256}


def test_waves_respect_max_wave(model):
    server = KrrServer(model, max_wave=128, min_bucket=32)
    reqs = _requests([(i, 50) for i in range(5)])  # 250 rows, 128-row budget
    rids = [server.submit(q) for q in reqs]
    out = server.flush()
    assert server.stats["dispatches"] == 3  # 100 + 100 + 50
    for rid, q in zip(rids, reqs):
        np.testing.assert_allclose(out[rid], model.predict(q), rtol=1e-6, atol=1e-6)


def test_oversized_request_goes_out_alone(model):
    server = KrrServer(model, max_wave=64, min_bucket=32)
    big = _requests([(9, 200)])[0]
    server.submit(_requests([(8, 10)])[0])
    rid = server.submit(big)
    out = server.flush()
    np.testing.assert_allclose(out[rid], model.predict(big), rtol=1e-6, atol=1e-6)
    assert 256 in server.stats["buckets"]  # 200 rows -> pow2 bucket 256


def test_buckets_are_pow2_and_bounded(model):
    server = KrrServer(model, max_wave=256, min_bucket=32)
    for s in range(20):
        server.submit(_requests([(s, 1 + (s * 37) % 90)])[0])
        server.flush()
    buckets = server.stats["buckets"]
    assert all(b >= 32 and (b & (b - 1)) == 0 for b in buckets)
    # jit-cache bound: at most log2(max_wave/min_bucket)+1 shapes ever compiled
    assert len(buckets) <= 4


def test_predict_convenience_and_validation(model):
    server = KrrServer(model)
    q = _requests([(11, 7)])[0]
    np.testing.assert_allclose(server.predict(q), model.predict(q),
                               rtol=1e-6, atol=1e-6)
    with pytest.raises(ValueError, match=r"\(r, 6\)"):
        server.submit(jnp.zeros((5,)))
    with pytest.raises(ValueError, match=r"\(r, 6\)"):
        server.submit(jnp.zeros((0, 6)))
    # wrong feature dim is rejected at submit, before it can poison a wave
    with pytest.raises(ValueError, match=r"\(r, 6\)"):
        server.submit(jnp.zeros((5, 8)))


def test_non_finite_request_rejected_at_submit(model):
    """One NaN/Inf request must not reach a packed wave (it would poison
    every co-packed request's Gram tile): submit itself raises, and the
    requests around it still serve exactly."""
    server = KrrServer(model, min_bucket=32)
    good = _requests([(20, 9), (21, 5)])
    r0 = server.submit(good[0])
    with pytest.raises(ValueError, match="non-finite"):
        server.submit(jnp.zeros((4, 6)).at[1, 2].set(jnp.nan))
    with pytest.raises(ValueError, match="non-finite"):
        server.submit(jnp.full((4, 6), jnp.inf))
    r1 = server.submit(good[1])
    out = server.flush()
    for rid, q in zip((r0, r1), good):
        np.testing.assert_allclose(out[rid], model.predict(q),
                                   rtol=1e-6, atol=1e-6)


def test_flush_drain_is_linear(model):
    """The queue is a deque: draining N single-row requests does N popleft
    O(1) steps (the old list.pop(0) made this quadratic). Guard the
    behavior: a long queue flushes completely and in submit order."""
    server = KrrServer(model, max_wave=256, min_bucket=32)
    rids = [server.submit(_requests([(s, 1)])[0]) for s in range(300)]
    out = server.flush()
    assert server.pending_rows == 0 and len(out) == 300
    assert set(out) == set(rids)


def test_reset_clears_queue_and_stats(model):
    server = KrrServer(model)
    server.submit(_requests([(12, 9)])[0])
    assert server.pending_rows == 9
    server.reset()
    assert server.pending_rows == 0
    assert server.flush() == {}
    assert server.stats["requests"] == 0 and server.stats["dispatches"] == 0


@pytest.mark.parametrize("name", ["jnp", "pallas", "sharded"])
def test_multi_output_waves_cross_backend(name):
    """(n, k) targets: every wave serves (r, k) blocks, exactly matching the
    direct path, on each kernel-operator backend."""
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (400, 6))
    y = jnp.sin(2 * x[:, 0]) + 0.3 * x[:, 1] ** 2
    Y = jnp.stack([y, -y, jnp.cos(x[:, 2])], axis=1)
    m = falkon_fit(KERN, x, Y, x[:48], 1e-3, iters=12, backend=name)
    server = KrrServer(m, max_wave=128, min_bucket=32)
    reqs = _requests([(1, 3), (2, 40), (3, 100)])
    rids = [server.submit(q) for q in reqs]
    out = server.flush()
    for rid, q in zip(rids, reqs):
        assert out[rid].shape == (q.shape[0], 3)
        np.testing.assert_allclose(out[rid], m.predict(q), rtol=1e-6, atol=1e-6)
