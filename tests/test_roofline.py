"""Roofline plumbing: the analytic FLOP model cross-checked against XLA's
counter on an unrolled (scan-free trip-count=1) config, and the collective
parser on synthetic HLO."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, smoke
from repro.launch.cost_model import forward_flops, step_costs, xla_cost_analysis
from repro.launch.hlo_analysis import collective_bytes, _shape_bytes


def test_analytic_vs_xla_flops_dense():
    """1-group smoke config, remat off, single batch: XLA counts the scan
    body once == actual (trip count 1); analytic model must land within
    ~35% (XLA also counts exp/mask flops we don't)."""
    cfg = dataclasses.replace(smoke(get_config("phi3-mini-3.8b")),
                              n_layers=1, remat=False, attn_chunk=64)
    from repro.models import forward, init_params

    b, s = 2, 64
    params = init_params(cfg, jax.random.PRNGKey(0))
    bat = {"tokens": jnp.zeros((b, s), jnp.int32)}
    comp = jax.jit(lambda p, bt: forward(p, cfg, bt)).lower(params, bat).compile()
    xla = float(xla_cost_analysis(comp).get("flops", 0.0))
    # forward_flops includes the logits matmul; forward() does not
    from repro.models.model import padded_vocab

    ana = forward_flops(cfg, b, s).flops_fwd - 2 * b * s * cfg.d_model * padded_vocab(cfg)
    assert 0.5 < ana / xla < 1.5, (ana, xla)


def test_step_costs_train_factor():
    cfg = smoke(get_config("phi3-mini-3.8b"))
    f_fwd = forward_flops(cfg, 4, 64).flops_fwd
    train = step_costs(cfg, "train", 4, 64, chips=1)
    assert train["flops_per_device"] == pytest.approx(4 * f_fwd)


def test_moe_counts_active_not_total():
    cfg = get_config("llama4-scout-17b-a16e")
    dense_equiv = dataclasses.replace(
        cfg, n_experts=0, top_k=0, shared_expert_ff=0)
    fm = forward_flops(cfg, 1, 4096).flops_fwd
    fd = forward_flops(dense_equiv, 1, 4096).flops_fwd
    # 16 experts top-1 at cf=1.25 + shared expert ~= 2.3x one dense mlp,
    # nowhere near 16x
    assert fm < 3.5 * fd


def test_shape_bytes():
    assert _shape_bytes("bf16[2,3,4]") == 48
    assert _shape_bytes("f32[10]{0}") == 40
    assert _shape_bytes("(f32[4], bf16[8])") == 32


def test_collective_parser_trip_counts():
    hlo = """
HloModule test

%body (p: (s32[], f32[64])) -> (s32[], f32[64]) {
  %ar = f32[64]{0} all-reduce(%x), replica_groups={}
  ROOT %t = tuple(...)
}

%cond (p: (s32[], f32[64])) -> pred[] {
  ROOT %lt = pred[] compare(...)
}

ENTRY %main (a: f32[64]) -> f32[64] {
  %ag = f32[128]{0} all-gather(%a), dimensions={0}
  %w = (s32[], f32[64]) while(%init), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"5"}}
  ROOT %r = f32[64] get-tuple-element(%w), index=1
}
"""
    coll = collective_bytes(hlo, cpu_bf16_correction=False)
    assert coll["all-gather"] == 128 * 4
    assert coll["all-reduce"] == 5 * 64 * 4  # trip-count multiplied
    # with the CPU bf16-normalization correction, f32 counts at half
    coll2 = collective_bytes(hlo, cpu_bf16_correction=True)
    assert coll2["all-reduce"] == 5 * 64 * 2
