"""Deterministic fallback for ``hypothesis`` when it isn't installed.

The container this repo develops in has no network access, so ``pip install
hypothesis`` isn't always possible; CI installs the real library (see
pyproject's ``test`` extra) and uses it. This stub implements exactly the
subset the test suite uses — ``@settings(max_examples=, deadline=)``,
``@given(**kw)``, ``strategies.{floats,integers,sampled_from}`` — drawing a
fixed, seeded example sequence per test: both range endpoints first, then
uniform draws. Registered into ``sys.modules`` by ``conftest.py`` only when
the real package is missing.
"""
from __future__ import annotations

import random
import sys
import types

_SEED = 0xB1E55


class _Strategy:
    def __init__(self, draw, endpoints=()):
        self.draw = draw
        self.endpoints = tuple(endpoints)


def floats(min_value, max_value):
    return _Strategy(lambda rng: rng.uniform(min_value, max_value),
                     endpoints=(min_value, max_value))


def integers(min_value, max_value):
    return _Strategy(lambda rng: rng.randint(min_value, max_value),
                     endpoints=(min_value, max_value))


def sampled_from(elements):
    seq = list(elements)
    return _Strategy(lambda rng: rng.choice(seq), endpoints=seq[:1])


def given(**strategies_kw):
    def decorate(fn):
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_stub_max_examples", 10)
            rng = random.Random(_SEED)
            for i in range(n):
                drawn = {}
                for name, strat in strategies_kw.items():
                    if i < len(strat.endpoints):
                        drawn[name] = strat.endpoints[i]
                    else:
                        drawn[name] = strat.draw(rng)
                fn(*args, **drawn, **kwargs)

        # NOTE: deliberately no functools.wraps — pytest must see the
        # (*args, **kwargs) signature, not the strategy parameters (it would
        # try to resolve them as fixtures).
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        return wrapper

    return decorate


def settings(max_examples=10, deadline=None, **_ignored):
    def decorate(fn):
        fn._stub_max_examples = max_examples
        return fn

    return decorate


def install() -> None:
    """Register the stub as the ``hypothesis`` package."""
    mod = types.ModuleType("hypothesis")
    st_mod = types.ModuleType("hypothesis.strategies")
    for f in (floats, integers, sampled_from):
        setattr(st_mod, f.__name__, f)
    mod.given = given
    mod.settings = settings
    mod.strategies = st_mod
    mod.__stub__ = True
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = st_mod
