"""Leverage-score invariants (incl. hypothesis property tests):
Prop. 1 exactness, Lemma 3 monotonicity, score range, d_eff identities."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (CenterSet, approx_rls_all, exact_rls, make_kernel,
                        uniform_center_set)

KERN = make_kernel("gaussian", sigma=2.0)


def _x(n, d=5, seed=0):
    return jax.random.normal(jax.random.PRNGKey(seed), (n, d))


def test_scores_in_range_and_sum(clustered_data):
    lam = 1e-3
    ell = exact_rls(KERN, clustered_data, lam)
    n = clustered_data.shape[0]
    assert float(ell.min()) >= 0.0 and float(ell.max()) <= 1.0
    deff = float(jnp.sum(ell))
    assert 0 < deff < min(n, 1.0 / lam + 1)


def test_prop1_full_set_is_exact(clustered_data):
    """Eq. 3 with J = [n], A = I reproduces the exact scores (Prop. 1)."""
    x = clustered_data[:300]
    n = x.shape[0]
    lam = 1e-3
    cs = CenterSet(
        idx=jnp.arange(n, dtype=jnp.int32),
        weight=jnp.ones((n,), jnp.float32),
        mask=jnp.ones((n,), bool),
        count=jnp.asarray(n, jnp.int32),
    )
    approx = approx_rls_all(KERN, x, cs, jnp.asarray(lam))
    exact = exact_rls(KERN, x, lam)
    np.testing.assert_allclose(approx, exact, rtol=2e-3, atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(lam=st.floats(1e-4, 1e-1), factor=st.floats(1.5, 20.0))
def test_lemma3_monotonicity(lam, factor):
    """l(i, lam') <= l(i, lam) <= (lam'/lam) l(i, lam') for lam < lam'."""
    x = _x(200)
    lam_hi = lam * factor
    lo = exact_rls(KERN, x, lam)
    hi = exact_rls(KERN, x, lam_hi)
    assert bool(jnp.all(hi <= lo + 1e-6))
    assert bool(jnp.all(lo <= factor * hi + 1e-6))


@settings(max_examples=8, deadline=None)
@given(n=st.integers(50, 300), m=st.integers(10, 49), seed=st.integers(0, 10**6))
def test_uniform_estimator_bounds(n, m, seed):
    """Nystrom RLS over-estimates never exceed the trivial K_ii/(lam n) cap
    and stay positive, for any uniform center set."""
    x = _x(n, seed=seed % 7)
    lam = 1e-2
    idx = jax.random.randint(jax.random.PRNGKey(seed), (m,), 0, n)
    cs = uniform_center_set(idx, n, 64)
    s = approx_rls_all(KERN, x, cs, jnp.asarray(lam))
    assert bool(jnp.all(s > 0))
    assert bool(jnp.all(s <= 1.0 / (lam * n) + 1e-6))


def test_deff_decreases_with_lam(clustered_data):
    deffs = [float(jnp.sum(exact_rls(KERN, clustered_data, lam)))
             for lam in (1e-1, 1e-2, 1e-3)]
    assert deffs[0] < deffs[1] < deffs[2]
