"""End-to-end behaviour: the paper pipeline (BLESS -> FALKON) learns; the
LM framework trains (loss falls), checkpoints, restores bit-exactly, and
serves; serving engine decodes coherently with per-slot state."""
import dataclasses
import tempfile

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, smoke
from repro.core import exact_rls, falkon_bless_fit, make_kernel
from repro.data import SyntheticLM
from repro.optim import OptConfig
from repro.serving.engine import ServeEngine, prefill, sample_greedy
from repro.training import make_train_step, train_state_init
from repro.checkpoint import restore_checkpoint, save_checkpoint


def test_paper_pipeline_learns(clustered_data):
    """End-to-end BLESS -> FALKON: explains most variance AND matches the
    direct Nystrom solver on its own centers (the solver contract).
    (At n=900 with-replacement sampling leaves ~2/3 unique centers, so the
    approximation floor is above the paper's n >> M regime — EXPERIMENTS.md
    quantifies this; here we pin the contract, not the asymptotics.)"""
    from repro.core import nystrom_krr

    x = clustered_data
    y = jnp.sin(3 * x[:, 0]) * jnp.tanh(x[:, 1])
    kern = make_kernel("gaussian", sigma=1.0)
    model = falkon_bless_fit(jax.random.PRNGKey(1), kern, x, y,
                             lam_bless=1e-3, lam_falkon=1e-6, iters=30, m_cap=400)
    mse = float(jnp.mean((model.predict(x) - y) ** 2))
    var = float(jnp.var(y))
    assert mse < 0.25 * var, (mse, var)  # >75% variance explained
    ny = nystrom_krr(kern, x, y, model.centers, 1e-6)
    rel = float(jnp.linalg.norm(model.predict(x) - ny.predict(x))
                / jnp.linalg.norm(ny.predict(x)))
    assert rel < 1e-3, rel


def test_lm_trains_checkpoints_and_serves():
    cfg = smoke(get_config("qwen3-32b"))
    opt = OptConfig(peak_lr=3e-3, warmup=5, total_steps=80)
    state = train_state_init(cfg, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(cfg, opt, loss_chunks=4))
    pipe = SyntheticLM(cfg.vocab_size, batch=8, seq=64, seed=0, noise=0.05)
    losses = []
    for s in range(60):  # past the lr peak: rule accuracy ~86% (pred correct by ~45)
        state, m = step(state, pipe.batch_at(s))
        losses.append(float(m["loss"]))
    assert losses[-1] < 0.5 * losses[0], losses[::10]

    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 60, state)
        _, restored = restore_checkpoint(d, state)
        for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
            assert bool(jnp.all(a == b))
        # restored state continues identically (determinism)
        s1, m1 = step(state, pipe.batch_at(60))
        s2, m2 = step(restored, pipe.batch_at(60))
        assert float(m1["loss"]) == float(m2["loss"])

    # greedy decode predicts the learned rule
    params = state.params
    perm = pipe._rule()
    t0 = 17
    logits, cache = prefill(params, cfg, jnp.asarray([[t0]]), cache_len=8)
    pred = int(sample_greedy(logits, cfg.vocab_size)[0])
    assert pred == int(perm[t0])


def test_serve_engine_continuous_batching():
    cfg = smoke(get_config("phi3-mini-3.8b"))
    state = train_state_init(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(params=state.params, cfg=cfg, max_len=32, batch_slots=3)
    # an empty prompt has no logits to sample from: clear error, not an
    # unbound-variable crash (and the engine state stays untouched)
    with pytest.raises(ValueError, match="at least one token"):
        eng.add_request(0, [])
    assert not bool(eng.active[0])
    eng.add_request(0, [1, 2, 3])
    eng.add_request(1, [4, 5])
    for _ in range(4):
        eng.step()
    out0, out1 = eng.finish(0), eng.finish(1)
    assert len(out0) == 5 and len(out1) == 5
    assert all(0 <= t < cfg.vocab_size for t in out0 + out1)


def test_train_step_sharded_runs_on_local_mesh():
    """The same pjit train step the dry-run lowers also *runs* on a real
    (1-device) mesh with full sharding machinery engaged."""
    from repro.launch.specs import input_specs
    from repro.sharding.rules import MeshCtx, activate_mesh, set_mesh_ctx

    cfg = dataclasses.replace(smoke(get_config("gemma-2b")), attn_chunk=64)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    ctx = MeshCtx(mesh=mesh)
    set_mesh_ctx(ctx)
    try:
        from repro.training import make_train_step, train_state_init

        state = train_state_init(cfg, jax.random.PRNGKey(0))
        pipe = SyntheticLM(cfg.vocab_size, batch=4, seq=64, seed=0)
        step = jax.jit(make_train_step(cfg, OptConfig(), loss_chunks=4))
        with activate_mesh(mesh):
            state, m = step(state, pipe.batch_at(0))
        assert jnp.isfinite(m["loss"])
    finally:
        set_mesh_ctx(None)
