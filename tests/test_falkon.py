"""FALKON: Def. 2 preconditioner identity, CG convergence to the Def. 4
Nystrom solution, FALKON-BLESS end-to-end, Pallas operator parity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (cg, exact_krr, falkon_bless_fit, falkon_fit,
                        make_kernel, make_preconditioner, nystrom_krr)

KERN = make_kernel("gaussian", sigma=1.5)


def _problem(n=500, m=80, seed=0):
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (n, 6))
    y = jnp.sin(2 * x[:, 0]) + 0.3 * x[:, 1] ** 2
    z = x[jax.random.choice(jax.random.PRNGKey(seed + 1), n, (m,), replace=False)]
    return x, y, z


@settings(max_examples=6, deadline=None)
@given(m=st.integers(16, 64), lam=st.floats(1e-4, 1e-1), seed=st.integers(0, 100))
def test_preconditioner_identity(m, lam, seed):
    """B B^T = (n/M K A^{-1} K + lam n K)^{-1}   (Eq. 15) on random PSD."""
    n = 300
    key = jax.random.PRNGKey(seed)
    z = jax.random.normal(key, (m, 5))
    a = jax.random.uniform(jax.random.PRNGKey(seed + 1), (m,), minval=0.3, maxval=2.0)
    prec = make_preconditioner(KERN, z, a, lam, n)
    b_dense = jax.vmap(prec.apply, in_axes=1, out_axes=1)(jnp.eye(m))
    k = KERN.cross(z, z)
    h = n / m * k @ jnp.diag(1 / a) @ k + lam * n * k
    # the preconditioner's defining property: B^T H B == I (on kept rank)
    w = b_dense.T @ h @ b_dense
    rel = float(jnp.linalg.norm(w - jnp.eye(m)) / np.sqrt(m))
    assert rel < 2e-2, rel


def test_falkon_converges_to_nystrom():
    x, y, z = _problem()
    lam = 1e-3
    fk = falkon_fit(KERN, x, y, z, lam, iters=40)
    ny = nystrom_krr(KERN, x, y, z, lam)
    pf, pn = fk.predict(x), ny.predict(x)
    assert float(jnp.linalg.norm(pf - pn) / jnp.linalg.norm(pn)) < 1e-3


def test_falkon_matches_exact_krr_with_all_centers():
    x, y, _ = _problem(n=250)
    lam = 1e-2
    fk = falkon_fit(KERN, x, y, x, lam, iters=60)
    ex = exact_krr(KERN, x, y, lam)
    pf, pe = fk.predict(x), ex.predict(x)
    assert float(jnp.linalg.norm(pf - pe) / jnp.linalg.norm(pe)) < 5e-3


def test_cg_residual_decreases():
    key = jax.random.PRNGKey(0)
    a = jax.random.normal(key, (40, 40))
    a = a @ a.T / 40.0 + jnp.eye(40)  # well conditioned
    b = jax.random.normal(jax.random.PRNGKey(1), (40,))
    res = []
    cg(lambda v: a @ v, b, 25,
       callback=lambda i, beta: res.append(float(jnp.linalg.norm(a @ beta - b))))
    assert res[-1] < 1e-3 * res[0]


def test_falkon_bless_end_to_end(clustered_data):
    """Low-d_eff (clustered) data — the regime leverage scores are for:
    a few hundred BLESS centers reach near-interpolation."""
    x = clustered_data
    y = jnp.sin(2 * x[:, 0]) + 0.3 * x[:, 1]
    model = falkon_bless_fit(jax.random.PRNGKey(0), KERN, x, y,
                             lam_bless=1e-3, lam_falkon=1e-5, iters=30, m_cap=300)
    pred = model.predict(x)
    base = jnp.mean((y - y.mean()) ** 2)
    assert float(jnp.mean((pred - y) ** 2)) < 0.05 * float(base)


def test_falkon_with_pallas_backend_matches():
    from repro.core import PallasBackend

    x, y, z = _problem(n=400, m=64)
    lam = 1e-3
    fk = falkon_fit(KERN, x, y, z, lam, iters=25,
                    backend=PallasBackend(interpret=True, bn=256))
    ref = falkon_fit(KERN, x, y, z, lam, iters=25, backend="jnp")
    assert float(jnp.linalg.norm(fk.alpha - ref.alpha)
                 / jnp.linalg.norm(ref.alpha)) < 1e-3
