"""Kernel-operator backend layer: the three hot contractions agree across
jnp / Pallas(interpret) / shard_map to fp32 tolerance, end-to-end BLESS and
FALKON runs included, plus registry/heuristic plumbing."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (JnpBackend, PallasBackend, ShardedBackend, backend_names,
                        bless, default_backend, falkon_fit, make_kernel,
                        resolve_backend)
from repro.core.leverage import approx_rls_all

BACKENDS = ["jnp", "pallas", "sharded"]
KERN = make_kernel("gaussian", sigma=1.5)


def _problem(n=400, m=64, d=6, seed=0):
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (n, d))
    y = jnp.sin(2 * x[:, 0]) + 0.3 * x[:, 1] ** 2
    z = x[:m]
    return x, y, z


# -- registry / heuristic ----------------------------------------------------


def test_registry_names_and_resolution():
    assert backend_names() == ["guarded", "jnp", "pallas", "sharded", "stream"]
    assert isinstance(resolve_backend("jnp"), JnpBackend)
    assert isinstance(resolve_backend("pallas"), PallasBackend)
    assert isinstance(resolve_backend("sharded"), ShardedBackend)
    from repro.core.backend import GuardedBackend
    assert isinstance(resolve_backend("guarded"), GuardedBackend)
    inst = PallasBackend(interpret=True)
    assert resolve_backend(inst) is inst
    with pytest.raises(ValueError, match="unknown backend"):
        resolve_backend("cuda")


def test_default_backend_heuristic_off_tpu():
    # the suite runs on 1 CPU device: heuristic must land on the reference
    # in-core, and wrap it in the out-of-core streamer past the row bound
    from repro.stream import StreamBackend
    assert isinstance(default_backend(), JnpBackend)
    assert isinstance(default_backend(1_000_000), JnpBackend)
    big = default_backend(10_000_000)
    assert isinstance(big, StreamBackend)
    assert isinstance(big.inner, JnpBackend)


def test_repro_backend_env_override(monkeypatch):
    monkeypatch.setenv("REPRO_BACKEND", "pallas")
    assert isinstance(default_backend(), PallasBackend)
    assert isinstance(resolve_backend(None), PallasBackend)  # threads through
    monkeypatch.setenv("REPRO_BACKEND", "auto")
    assert isinstance(default_backend(), JnpBackend)  # falls through to heuristic
    monkeypatch.setenv("REPRO_BACKEND", "cuda")
    with pytest.raises(ValueError, match="REPRO_BACKEND"):
        default_backend()


def test_backends_are_hashable_jit_keys():
    assert hash(JnpBackend()) == hash(JnpBackend())
    assert JnpBackend() == JnpBackend()
    assert PallasBackend(bn=256) != PallasBackend()


# -- contraction parity ------------------------------------------------------


ALL_FAMILIES = ["gaussian", "laplacian", "linear", "matern32", "cauchy"]


@pytest.mark.parametrize("name", BACKENDS)
@pytest.mark.parametrize("kind", ALL_FAMILIES)
def test_gram_block_parity(name, kind):
    kern = make_kernel(kind, sigma=1.7, kappa_sq=10.0)
    x, _, _ = _problem(n=300)
    # z disjoint from x: at d2 == 0 the laplacian's sqrt amplifies fp
    # association noise between compiled and eager paths beyond tolerance
    z = jax.random.normal(jax.random.PRNGKey(9), (70, x.shape[1]))
    out = resolve_backend(name).gram_block(kern, x, z)
    np.testing.assert_allclose(out, kern.cross(x, z), rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("name", BACKENDS)
@pytest.mark.parametrize("kind", ["matern32", "cauchy"])
def test_new_family_knm_matvec_parity(name, kind):
    """The registry's new families drive the predict contraction on every
    backend from the one KernelFamily definition."""
    kern = make_kernel(kind, sigma=1.3)
    x, _, _ = _problem(n=300)
    z = jax.random.normal(jax.random.PRNGKey(7), (48, x.shape[1]))
    v = jax.random.normal(jax.random.PRNGKey(5), (48,))
    ref = kern.cross(x, z) @ v
    out = resolve_backend(name).knm_matvec(kern, x, z, v)
    np.testing.assert_allclose(out, ref, rtol=1e-4,
                               atol=1e-4 * float(jnp.abs(ref).max()))


@pytest.mark.parametrize("name", BACKENDS)
def test_masked_quadform_parity(name):
    x, _, z = _problem(n=256, m=48)
    mbuf = 64
    mask = jnp.arange(mbuf) < 48
    zbuf = jnp.where(mask[:, None], jnp.pad(z, ((0, mbuf - 48), (0, 0))), 0.0)
    reg = jnp.where(mask, 1e-3 * x.shape[0], 1.0)
    ref = JnpBackend().masked_quadform(KERN, x, zbuf, mask, reg)
    out = resolve_backend(name).masked_quadform(KERN, x, zbuf, mask, reg)
    np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("name", BACKENDS)
def test_knm_operators_parity(name):
    x, y, z = _problem()
    v = jax.random.normal(jax.random.PRNGKey(3), (z.shape[0],))
    g = KERN.cross(x, z)
    quad, kty = resolve_backend(name).knm_operators(KERN, x, z, y)
    np.testing.assert_allclose(quad(v), g.T @ (g @ v), rtol=1e-4,
                               atol=1e-4 * float(jnp.abs(g.T @ (g @ v)).max()))
    np.testing.assert_allclose(kty, g.T @ y, rtol=1e-4,
                               atol=1e-4 * float(jnp.abs(g.T @ y).max()))


@pytest.mark.parametrize("name", BACKENDS)
@pytest.mark.parametrize("n", [256, 300])  # tile-aligned and ragged (n % block != 0)
def test_knm_matvec_parity(name, n):
    x, _, _ = _problem(n=n)
    z = jax.random.normal(jax.random.PRNGKey(7), (48, x.shape[1]))
    v = jax.random.normal(jax.random.PRNGKey(5), (48,))
    ref = KERN.cross(x, z) @ v
    out = resolve_backend(name).knm_matvec(KERN, x, z, v)
    assert out.shape == (n,)
    np.testing.assert_allclose(out, ref, rtol=1e-4,
                               atol=1e-4 * float(jnp.abs(ref).max()))


def test_jnp_knm_matvec_multiblock_ragged():
    """The streaming branch: n spans several blocks and overhangs the last."""
    x, _, _ = _problem(n=300)
    z = jax.random.normal(jax.random.PRNGKey(7), (32, x.shape[1]))
    v = jax.random.normal(jax.random.PRNGKey(5), (32,))
    out = JnpBackend(block=128).knm_matvec(KERN, x, z, v)
    np.testing.assert_allclose(out, KERN.cross(x, z) @ v, rtol=1e-5, atol=1e-5)


# -- mixed precision (PallasBackend(bf16=True)) ------------------------------
#
# bf16 MXU operands, fp32 accumulation: only the distance cross-term loses
# precision, so unit-scale data stays within ~3e-2 absolute of fp32
# (DESIGN.md §2.3). These tolerances are the documented contract.

BF16 = PallasBackend(interpret=True, bf16=True)


def test_bf16_is_a_distinct_jit_key():
    assert BF16 != PallasBackend(interpret=True)
    hash(BF16)  # usable as a static jit argument
    assert BF16.bf16 and not PallasBackend().bf16


def test_bf16_gram_tolerance():
    x, _, _ = _problem(n=300)
    z = jax.random.normal(jax.random.PRNGKey(9), (70, x.shape[1]))
    out = BF16.gram_block(KERN, x, z)
    np.testing.assert_allclose(out, KERN.cross(x, z), atol=3e-2)


def test_bf16_knm_matvec_tolerance():
    x, _, _ = _problem(n=300)
    z = jax.random.normal(jax.random.PRNGKey(9), (48, x.shape[1]))
    v = jax.random.normal(jax.random.PRNGKey(5), (48,))
    ref = KERN.cross(x, z) @ v
    out = BF16.knm_matvec(KERN, x, z, v)
    np.testing.assert_allclose(out, ref, atol=3e-2 * float(jnp.abs(ref).max()))


def test_bf16_masked_quadform_tolerance():
    x, _, z = _problem(n=256, m=48)
    mbuf = 64
    mask = jnp.arange(mbuf) < 48
    zbuf = jnp.where(mask[:, None], jnp.pad(z, ((0, mbuf - 48), (0, 0))), 0.0)
    reg = jnp.where(mask, 1e-3 * x.shape[0], 1.0)
    ref = JnpBackend().masked_quadform(KERN, x, zbuf, mask, reg)
    out = BF16.masked_quadform(KERN, x, zbuf, mask, reg)
    np.testing.assert_allclose(out, ref, atol=5e-2 * float(jnp.abs(ref).max()))


# -- end-to-end parity (the acceptance bar) ----------------------------------


@pytest.mark.parametrize("name", ["pallas", "sharded"])
def test_bless_center_sets_match_jnp(name):
    """Identical PRNG path + fp32-close scores => identical center sets."""
    x, _, _ = _problem(n=500)
    ref = bless(jax.random.PRNGKey(0), x, KERN, 1e-3, backend="jnp")
    res = bless(jax.random.PRNGKey(0), x, KERN, 1e-3, backend=name)
    assert [lvl.m_h for lvl in res.levels] == [lvl.m_h for lvl in ref.levels]
    assert bool(jnp.all(res.final.centers.idx == ref.final.centers.idx))
    # 5e-4: the internal center dedup merges duplicate regularizers (harmonic
    # sum), which mildly worsens the (M, M) conditioning the backends' fp32
    # solves amplify — center identity above is still required to be exact
    np.testing.assert_allclose(res.final.centers.weight, ref.final.centers.weight,
                               rtol=5e-4, atol=5e-5)
    s_ref = approx_rls_all(KERN, x, ref.final.centers, jnp.asarray(1e-3), backend="jnp")
    s = approx_rls_all(KERN, x, ref.final.centers, jnp.asarray(1e-3), backend=name)
    np.testing.assert_allclose(s, s_ref, rtol=5e-4, atol=5e-5)


@pytest.mark.parametrize("name", ["pallas", "sharded"])
def test_falkon_predictions_match_jnp(name):
    x, y, z = _problem()
    ref = falkon_fit(KERN, x, y, z, 1e-3, iters=25, backend="jnp")
    fk = falkon_fit(KERN, x, y, z, 1e-3, iters=25, backend=name)
    # the model remembers its fit-time backend, so each predict below also
    # exercises that backend's knm_matvec end to end
    assert fk.backend is not None and fk.backend.name == name
    pr, pf = ref.predict(x), fk.predict(x)
    assert float(jnp.max(jnp.abs(pr - pf))) < 1e-4, name
    # per-call override routes the same model through another backend
    po = fk.predict(x, backend="jnp")
    assert float(jnp.max(jnp.abs(po - pr))) < 1e-4, name


@pytest.mark.parametrize("name", ["pallas", "sharded"])
@pytest.mark.parametrize("kind", ["matern32", "cauchy"])
def test_new_family_falkon_predictions_match_jnp(name, kind):
    """End-to-end FALKON parity for the registry's new families."""
    kern = make_kernel(kind, sigma=1.8)
    x, y, z = _problem(n=300, m=40)
    ref = falkon_fit(kern, x, y, z, 1e-3, iters=20, backend="jnp")
    fk = falkon_fit(kern, x, y, z, 1e-3, iters=20, backend=name)
    assert float(jnp.max(jnp.abs(ref.predict(x) - fk.predict(x)))) < 1e-4


def test_unknown_family_error_enumerates_registry():
    import dataclasses

    from repro.core import kernel_family_names

    bad = dataclasses.replace(make_kernel("gaussian"), name="spectral")
    with pytest.raises(ValueError, match="registered"):
        resolve_backend("pallas").gram_block(bad, jnp.zeros((8, 4)), jnp.zeros((8, 4)))
    assert {"gaussian", "laplacian", "linear", "matern32", "cauchy"} <= set(
        kernel_family_names())


def test_pallas_backend_runs_interpret_explicitly():
    """CI path: interpret=True forced (not just the off-TPU default)."""
    x, y, z = _problem(n=300, m=40)
    fk = falkon_fit(KERN, x, y, z, 1e-3, iters=15,
                    backend=PallasBackend(interpret=True))
    ref = falkon_fit(KERN, x, y, z, 1e-3, iters=15, backend="jnp")
    assert float(jnp.max(jnp.abs(fk.predict(x) - ref.predict(x)))) < 1e-4
