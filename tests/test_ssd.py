"""Mamba-2 SSD: chunked scan == naive recurrence (hypothesis-swept)."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.models.mamba2 import ssd_chunked, ssd_decode_step


def _naive(x, dt, a, b, c):
    bs, s, h, p = x.shape
    n = b.shape[-1]
    state = jnp.zeros((bs, h, p, n))
    ys = []
    for t in range(s):
        y, state = ssd_decode_step(state, x[:, t], dt[:, t], a, b[:, t, 0], c[:, t, 0])
        ys.append(y)
    return jnp.stack(ys, 1), state


@settings(max_examples=8, deadline=None)
@given(s=st.sampled_from([16, 32, 64]), chunk=st.sampled_from([8, 16, 64]),
       h=st.sampled_from([1, 4]), seed=st.integers(0, 50))
def test_ssd_chunked_matches_recurrence(s, chunk, h, seed):
    if s % min(chunk, s):
        return
    p, n, bs = 8, 8, 2
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    x = jax.random.normal(ks[0], (bs, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (bs, s, h)))
    a = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    b = jax.random.normal(ks[3], (bs, s, 1, n)) * 0.5
    c = jax.random.normal(ks[4], (bs, s, 1, n)) * 0.5
    y_ref, st_ref = _naive(x, dt, a, b, c)
    y, st = ssd_chunked(x, dt, a, b, c, chunk=chunk)
    np.testing.assert_allclose(y, y_ref, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(st, st_ref, rtol=2e-4, atol=2e-4)


def test_ssd_init_state_continuation():
    """Splitting a sequence across two ssd_chunked calls via init_state
    equals one full pass — the prefill-then-decode contract."""
    bs, s, h, p, n = 1, 32, 2, 4, 8
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    x = jax.random.normal(ks[0], (bs, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (bs, s, h)))
    a = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    b = jax.random.normal(ks[3], (bs, s, 1, n)) * 0.5
    c = jax.random.normal(ks[4], (bs, s, 1, n)) * 0.5
    y_full, st_full = ssd_chunked(x, dt, a, b, c, chunk=16)
    y1, st1 = ssd_chunked(x[:, :16], dt[:, :16], a, b[:, :16], c[:, :16], chunk=16)
    y2, st2 = ssd_chunked(x[:, 16:], dt[:, 16:], a, b[:, 16:], c[:, 16:],
                          chunk=16, init_state=st1)
    np.testing.assert_allclose(jnp.concatenate([y1, y2], 1), y_full, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(st2, st_full, rtol=1e-4, atol=1e-4)
