"""MoE dispatch: sort-based capacity routing vs a naive per-token oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.layers import act_fn
from repro.models.moe import moe_apply, moe_init, _route_group


def _naive_moe(p, x, top_k, n_experts, act):
    """Per-token loop oracle, no capacity limit."""
    b, s, d = x.shape
    out = np.zeros((b, s, d), np.float32)
    logits = np.asarray(x.astype(jnp.float32) @ p["router"])
    probs = np.asarray(jax.nn.softmax(jnp.asarray(logits), -1))
    for bi in range(b):
        for t in range(s):
            idx = np.argsort(-probs[bi, t])[:top_k]
            w = probs[bi, t, idx]
            w = w / w.sum()
            for e, wi in zip(idx, w):
                xe = np.asarray(x[bi, t], np.float32)
                if "w_gate" in p:
                    h = (np.asarray(act_fn(act, jnp.asarray(xe @ np.asarray(p["w_gate"][e], np.float32))))
                         * (xe @ np.asarray(p["w_up"][e], np.float32)))
                else:
                    h = np.asarray(act_fn(act, jnp.asarray(xe @ np.asarray(p["w_up"][e], np.float32))))
                out[bi, t] += wi * (h @ np.asarray(p["w_down"][e], np.float32))
    return out


@pytest.mark.parametrize("top_k,n_experts", [(1, 4), (2, 8)])
def test_moe_matches_naive_with_big_capacity(top_k, n_experts):
    b, s, d, ff = 2, 16, 8, 16
    key = jax.random.PRNGKey(0)
    p = moe_init(key, d, ff, n_experts, "swiglu", dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (b, s, d), jnp.float32)
    got = moe_apply(p, x, top_k=top_k, n_experts=n_experts, act="swiglu",
                    capacity_factor=float(n_experts))  # no drops
    want = _naive_moe(p, x, top_k, n_experts, "swiglu")
    np.testing.assert_allclose(np.asarray(got), want, rtol=2e-3, atol=2e-3)


def test_capacity_drops_overflow_tokens():
    """All tokens prefer one expert; only `capacity` survive."""
    s, d, e = 32, 4, 4
    p = moe_init(jax.random.PRNGKey(0), d, 8, e, "gelu", dtype=jnp.float32)
    p["router"] = jnp.zeros((d, e)).at[:, 0].set(10.0)  # everyone -> expert 0
    x = jnp.ones((1, s, d), jnp.float32)
    slot, gate, src = _route_group(x[0], p["router"], 1, 4, e)
    kept = int(jnp.sum(slot < e * 4))
    assert kept == 4  # capacity
    out = moe_apply(p, x, top_k=1, n_experts=e, act="gelu", capacity_factor=0.125)
    # dropped tokens contribute zero
    nz = jnp.sum(jnp.any(jnp.abs(out[0]) > 1e-6, axis=-1))
    assert int(nz) <= 8


def test_shared_expert_added():
    b, s, d, ff = 1, 8, 8, 16
    p = moe_init(jax.random.PRNGKey(0), d, ff, 4, "swiglu", shared_ff=16,
                 dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (b, s, d), jnp.float32)
    with_shared = moe_apply(p, x, top_k=1, n_experts=4, act="swiglu")
    p2 = {k: v for k, v in p.items() if k != "shared"}
    without = moe_apply(p2, x, top_k=1, n_experts=4, act="swiglu")
    assert float(jnp.abs(with_shared - without).max()) > 1e-6
