"""Per-arch smoke tests (reduced configs) + decode/forward consistency."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs, smoke
from repro.models import (decode_step, forward, init_cache, init_params,
                          logits_fn, loss_fn, padded_vocab)

KEY = jax.random.PRNGKey(0)
B, S = 2, 32


def _batch(cfg, b=B, s=S, seed=1):
    kt, kl = jax.random.split(jax.random.PRNGKey(seed))
    bat = {"labels": jax.random.randint(kl, (b, s), 0, cfg.vocab_size)}
    if cfg.embed_inputs:
        bat["tokens"] = jax.random.randint(kt, (b, s), 0, cfg.vocab_size)
    else:
        bat["frames"] = jax.random.normal(kt, (b, s, cfg.d_model), jnp.bfloat16)
    if cfg.pos == "mrope":
        p = jnp.broadcast_to(jnp.arange(s), (b, s))
        bat["mrope_positions"] = jnp.stack([p, p, p], axis=1)
    if cfg.extra_image_tokens:
        bat["pixel_embeds"] = jax.random.normal(
            KEY, (b, cfg.extra_image_tokens, cfg.d_model), jnp.bfloat16)
    return bat


@pytest.mark.parametrize("name", list_archs())
def test_arch_smoke_forward_and_train_step(name):
    cfg = smoke(get_config(name))
    params = init_params(cfg, KEY)
    bat = _batch(cfg)
    h = forward(params, cfg, bat)
    assert h.shape == (B, S, cfg.d_model)
    assert bool(jnp.all(jnp.isfinite(h.astype(jnp.float32))))
    loss, grads = jax.value_and_grad(lambda p: loss_fn(p, cfg, bat, n_chunks=4))(params)
    assert 4.0 < float(loss) < 9.0  # ~ln(512) at init
    gn = sum(float(jnp.sum(jnp.abs(g.astype(jnp.float32)))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0


@pytest.mark.parametrize("name", [n for n in list_archs()
                                  if get_config(n).has_decode])
def test_arch_decode_shapes(name):
    cfg = smoke(get_config(name))
    params = init_params(cfg, KEY)
    cache = init_cache(cfg, B, S)
    tok = jnp.zeros((B,), jnp.int32)
    mp = jnp.full((B, 3, 1), 0) if cfg.pos == "mrope" else None
    logits, cache2 = decode_step(params, cfg, cache, tok, jnp.asarray(0),
                                 length=jnp.asarray(1), mrope_pos=mp)
    assert logits.shape == (B, padded_vocab(cfg))
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    assert jax.tree.structure(cache) == jax.tree.structure(cache2)


@pytest.mark.parametrize("name", [
    "phi3-mini-3.8b", "mamba2-370m",
    # the two heavy hybrid/MoE cells run >60s on CI hardware -> tier-2
    pytest.param("jamba-v0.1-52b", marks=pytest.mark.slow),
    pytest.param("granite-moe-3b-a800m", marks=pytest.mark.slow),
])
def test_decode_matches_forward(name):
    """Sequential decode reproduces the parallel forward's last-token
    logits — the cache-correctness test (KV and SSM state paths)."""
    # fp32 for tight equality; capacity high enough that the batched forward
    # drops nothing (decode groups are single tokens and never drop, so
    # equality only holds in the drop-free regime — drops themselves are
    # exercised in test_moe.py)
    cfg = dataclasses.replace(smoke(get_config(name)), remat=False,
                              dtype="float32", capacity_factor=16.0)
    params = init_params(cfg, KEY)
    s = 12
    bat = _batch(cfg, b=1, s=s)
    h = forward(params, cfg, bat)
    want = logits_fn(params, cfg, h[:, -1]).astype(jnp.float32)

    cache = init_cache(cfg, 1, s)
    logits = None
    for t in range(s):
        logits, cache = decode_step(params, cfg, cache, bat["tokens"][:, t],
                                    jnp.asarray(t), length=jnp.asarray(t + 1))
    got = logits.astype(jnp.float32)
    np.testing.assert_allclose(got, want, rtol=5e-3, atol=5e-3)


def test_padded_heads_are_exact():
    """A config whose heads get padded (8 -> 16 on TP=16) must produce
    identical output to itself — padded head outputs are masked, so params
    at padded slots must not affect results."""
    cfg = smoke(get_config("gemma-2b"))  # smoke: 4 heads -> padded to 16
    params = init_params(cfg, KEY)
    bat = _batch(cfg)
    h1 = forward(params, cfg, bat)
    # perturb the padded wq columns and padded wo rows: output must not move
    hp = cfg.padded_heads(16)
    hd = cfg.head_dim
    real = cfg.n_heads * hd

    def poison(p):
        p = jax.tree.map(lambda x: x, p)  # copy
        for j in range(cfg.layer_period):
            blk = p["blocks"][f"blk{j}"]["attn"]
            blk["wq"] = blk["wq"].at[:, :, real:].set(99.0)
            blk["wo"] = blk["wo"].at[:, real:, :].set(99.0)
        return p

    h2 = forward(poison(params), cfg, bat)
    np.testing.assert_allclose(np.asarray(h1, np.float32), np.asarray(h2, np.float32))


def test_vocab_padding_masked_in_loss():
    cfg = smoke(get_config("phi3-mini-3.8b"))
    params = init_params(cfg, KEY)
    bat = _batch(cfg)
    l1 = float(loss_fn(params, cfg, bat, n_chunks=4))
    params2 = jax.tree.map(lambda x: x, params)
    params2["out_head"] = params2["out_head"].at[:, cfg.vocab_size:].set(50.0)
    l2 = float(loss_fn(params2, cfg, bat, n_chunks=4))
    assert abs(l1 - l2) < 1e-4  # padded vocab logits never matter


def test_param_counts_match_analytic():
    for name in list_archs():
        cfg = get_config(name)
        analytic = cfg.param_count()
        shapes = jax.eval_shape(lambda c=cfg: init_params(c, KEY))
        actual = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(shapes))
        # padding (heads/vocab) inflates actual; norms etc. under-counted
        assert 0.9 < actual / analytic < 1.35, (name, actual, analytic)
