"""Multi-RHS block-CG (core/falkon.py): per-column parity with independent
single-RHS solves across every kernel family and backend, the k-bucketed
fused-fit cache (zero retraces within a bucket), per-column convergence
masking, and the KFoldSweep scenario vs naive per-fold refits."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import FitConfig, KFoldSweep, UniformSampler
from repro.core import cg, falkon_fit, make_kernel
from repro.core import falkon as falkon_mod

BACKENDS = ["jnp", "pallas", "sharded"]
ALL_FAMILIES = ["gaussian", "laplacian", "linear", "matern32", "cauchy"]


def _problem(n=300, m=32, d=6, k=3, seed=0):
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (n, d))
    cols = [jnp.sin(2 * x[:, 0]), jnp.cos(x[:, 1]), 0.3 * x[:, 2] ** 2,
            x[:, 3] * x[:, 0], jnp.tanh(x[:, 1] + x[:, 2]), -x[:, 4],
            jnp.sin(x[:, 5]) * x[:, 0], jnp.abs(x[:, 2])]
    return x, jnp.stack(cols[:k], axis=1), x[:m]


# -- parity: one block-CG vs k independent solves ----------------------------


@pytest.mark.parametrize("name", BACKENDS)
@pytest.mark.parametrize("kind", ALL_FAMILIES)
@pytest.mark.parametrize("k", [1, 3, 8])
def test_multi_rhs_matches_column_loop(name, kind, k):
    """The panel solve shares the preconditioner and the K_nM streaming, but
    every column's solution must match its own single-RHS fit (the PR 3
    column loop) to CG/fp32 tolerance."""
    kern = make_kernel(kind, sigma=1.7, kappa_sq=10.0)
    x, y, z = _problem(k=k)
    multi = falkon_fit(kern, x, y, z, 1e-3, iters=10, backend=name)
    assert multi.alpha.shape == (z.shape[0], k)
    pred = multi.predict(x)
    assert pred.shape == (x.shape[0], k)
    for j in range(k):
        col = falkon_fit(kern, x, y[:, j], z, 1e-3, iters=10, backend=name)
        ref = col.predict(x)
        rel = float(jnp.linalg.norm(pred[:, j] - ref)
                    / jnp.maximum(jnp.linalg.norm(ref), 1e-30))
        assert rel < 1e-3, (kind, name, j, rel)


def test_multi_rhs_host_path_matches_fused():
    """fused=False drives the same panel CG from the host loop."""
    kern = make_kernel("gaussian", sigma=1.5)
    x, y, z = _problem(k=3)
    fused = falkon_fit(kern, x, y, z, 1e-3, iters=20, backend="jnp")
    host = falkon_fit(kern, x, y, z, 1e-3, iters=20, backend="jnp", fused=False)
    rel = float(jnp.linalg.norm(fused.predict(x) - host.predict(x))
                / jnp.linalg.norm(host.predict(x)))
    assert rel < 1e-3


def test_multi_output_callback_rejected():
    kern = make_kernel("gaussian", sigma=1.5)
    x, y, z = _problem(k=2)
    with pytest.raises(ValueError, match="single-output"):
        falkon_fit(kern, x, y, z, 1e-3, callback=lambda i, m: None)


# -- the k-bucketed fused-fit cache ------------------------------------------


def test_fused_cache_k_bucket_zero_retrace():
    """k is padded to a pow2 column bucket: every RHS count in a bucket
    shares one executable (m=44 / iters=13 are unique to this test so other
    files' fits cannot mask the traces)."""
    kern = make_kernel("gaussian", sigma=1.5)
    x, y8, z = _problem(m=44, k=8)
    t0 = falkon_mod._FUSED_FIT_TRACES
    falkon_fit(kern, x, y8[:, :3], z, 1e-3, iters=13, backend="jnp")
    assert falkon_mod._FUSED_FIT_TRACES == t0 + 1  # k=3 compiled bucket kb=4
    falkon_fit(kern, x, y8[:, :4], z, 1e-3, iters=13, backend="jnp")
    assert falkon_mod._FUSED_FIT_TRACES == t0 + 1  # k=4: same bucket, no trace
    falkon_fit(kern, x, y8[:, :5], z, 1e-3, iters=13, backend="jnp")
    assert falkon_mod._FUSED_FIT_TRACES == t0 + 2  # k=5 -> bucket kb=8
    falkon_fit(kern, x, y8, z, 1e-3, iters=13, backend="jnp")
    assert falkon_mod._FUSED_FIT_TRACES == t0 + 2  # k=8 rides the kb=8 bucket
    falkon_fit(kern, x, y8[:, 0], z, 1e-3, iters=13, backend="jnp")
    assert falkon_mod._FUSED_FIT_TRACES == t0 + 3  # single-output: kb=1


def test_k_bucket_padding_columns_are_inert():
    """A k=3 fit runs in the kb=4 bucket with a zero fourth column; its
    presence must not perturb the real columns (vs a k=4 fit whose fourth
    column IS explicitly zero)."""
    kern = make_kernel("gaussian", sigma=1.5)
    x, y, z = _problem(k=3)
    a = falkon_fit(kern, x, y, z, 1e-3, iters=15, backend="jnp")
    b = falkon_fit(kern, x, jnp.pad(y, ((0, 0), (0, 1))), z, 1e-3, iters=15,
                   backend="jnp")
    np.testing.assert_array_equal(a.alpha, b.alpha[:, :3])
    np.testing.assert_array_equal(b.alpha[:, 3], jnp.zeros(z.shape[0]))


# -- per-column convergence masking ------------------------------------------


def test_cg_freezes_converged_columns():
    """A zero RHS column (rs0 = 0) must stay exactly zero while the live
    columns converge; an easy column frozen early must not drift."""
    key = jax.random.PRNGKey(0)
    a = jax.random.normal(key, (40, 40))
    a = a @ a.T / 40.0 + jnp.eye(40)
    b_live = jax.random.normal(jax.random.PRNGKey(1), (40,))
    b = jnp.stack([b_live, jnp.zeros(40)], axis=1)
    sol = cg(lambda v: a @ v, b, 60)
    np.testing.assert_array_equal(sol[:, 1], jnp.zeros(40))
    np.testing.assert_allclose(a @ sol[:, 0], b_live, rtol=1e-4, atol=1e-4)
    # panel solve of the live column agrees with the single-RHS path
    single = cg(lambda v: a @ v, b_live, 60)
    np.testing.assert_allclose(sol[:, 0], single, rtol=1e-4, atol=1e-5)


# -- KFoldSweep: model selection as one multi-RHS solve per lambda -----------


LAMS = (1e-2, 1e-4, 1e-6)


def _sweep_problem(n=400, d=6, seed=0):
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (n, d))
    y = (jnp.sin(2 * x[:, 0]) + 0.3 * x[:, 1] ** 2
         + 0.05 * jax.random.normal(jax.random.PRNGKey(seed + 1), (n,)))
    return x, y


def test_kfold_sweep_matches_naive_per_fold_refits():
    """Every (lam, fold) score must equal the naive loop: a full single-RHS
    refit on the fold-masked targets, scored on the held-out rows."""
    from repro.api.sweep import fold_ids

    x, y = _sweep_problem()
    folds = 4
    sweep = KFoldSweep(kernel="gaussian", sigma=1.5, sampler=UniformSampler(m=64),
                       lams=LAMS, folds=folds, iters=15, backend="jnp", seed=0)
    res = sweep.run(x, y)
    assert res.scores.shape == (len(LAMS), folds)

    kern = make_kernel("gaussian", sigma=1.5)
    k_sample, k_fold = jax.random.split(jax.random.PRNGKey(0))
    fid = fold_ids(k_fold, x.shape[0], folds)
    np.testing.assert_array_equal(res.fold_id, fid)
    cs = UniformSampler(m=64).sample(k_sample, x, kern, backend="jnp")
    m = int(cs.count)
    centers, a_diag = x[cs.idx[:m]], cs.weight[:m]
    for li, lam in enumerate(LAMS):
        for f in range(folds):
            model = falkon_fit(kern, x, y * (fid != f), centers, lam,
                               a_diag=a_diag, iters=15, backend="jnp")
            sel = fid == f
            mse = float(jnp.sum((model.predict(x) - y) ** 2 * sel) / jnp.sum(sel))
            got = float(res.scores[li, f])
            assert abs(mse - got) < 1e-3 * max(1.0, abs(mse)), (li, f, mse, got)
    assert res.best_lam == LAMS[res.best_index]
    assert float(res.mean_scores[res.best_index]) == float(jnp.min(res.mean_scores))


def test_kfold_sweep_rides_fused_cache():
    """The whole lambda grid after the first fit is cache hits: fold count
    fixes the k bucket, lam is traced, centers are warm-started."""
    x, y = _sweep_problem(seed=7)
    sweep = KFoldSweep(kernel="gaussian", sigma=1.5, sampler=UniformSampler(m=52),
                       lams=LAMS, folds=4, iters=12, backend="jnp", seed=3)
    res1 = sweep.run(x, y)
    t0 = falkon_mod._FUSED_FIT_TRACES
    res2 = sweep.run(x, y)  # same shapes end to end -> zero retraces
    assert falkon_mod._FUSED_FIT_TRACES == t0
    np.testing.assert_allclose(res1.scores, res2.scores, rtol=1e-6, atol=1e-7)


def test_kfold_sweep_validates_inputs():
    x, y = _sweep_problem(n=40)
    with pytest.raises(ValueError, match="single-output"):
        KFoldSweep(lams=(1e-3,)).run(x, jnp.stack([y, y], axis=1))
    with pytest.raises(ValueError, match="folds"):
        KFoldSweep(lams=(1e-3,), folds=1).run(x, y)


def test_fold_ids_are_balanced():
    from repro.api.sweep import fold_ids

    fid = fold_ids(jax.random.PRNGKey(0), 103, 5)
    sizes = [int(jnp.sum(fid == f)) for f in range(5)]
    assert min(sizes) >= max(sizes) - 1 and sum(sizes) == 103


def test_kfold_sweep_center_set_bypass():
    """center_set= skips the sampler (e.g. one BLESS ladder shared across
    sweeps) and is reused for every lambda."""
    x, y = _sweep_problem(n=300)
    kern = make_kernel("gaussian", sigma=1.5)
    cs = UniformSampler(m=48).sample(jax.random.PRNGKey(5), x, kern, backend="jnp")
    sweep = KFoldSweep(kernel=kern, lams=(1e-3, 1e-5), folds=3, iters=10,
                       backend="jnp")
    res = sweep.run(x, y, center_set=cs)
    assert res.center_set is cs
    assert res.scores.shape == (2, 3)
