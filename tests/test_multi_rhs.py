"""Multi-RHS block-CG (core/falkon.py): per-column parity with independent
single-RHS solves across every kernel family and backend, the k-bucketed
fused-fit cache (zero retraces within a bucket), per-column convergence
masking, the PR 9 mask-panel seam (per-column row exclusion in the
quadratic op), and the exact KFoldSweep scenario vs naive per-fold
refits."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import FitConfig, KFoldSweep, UniformSampler
from repro.core import cg, falkon_fit, make_kernel
from repro.core import falkon as falkon_mod
from repro.core.gram import resolve_backend

BACKENDS = ["jnp", "pallas", "sharded"]
MASK_BACKENDS = ["jnp", "pallas", "sharded", "stream"]
ALL_FAMILIES = ["gaussian", "laplacian", "linear", "matern32", "cauchy"]
MASK_FAMILIES = ["gaussian", "laplacian", "matern32"]


def _problem(n=300, m=32, d=6, k=3, seed=0):
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (n, d))
    cols = [jnp.sin(2 * x[:, 0]), jnp.cos(x[:, 1]), 0.3 * x[:, 2] ** 2,
            x[:, 3] * x[:, 0], jnp.tanh(x[:, 1] + x[:, 2]), -x[:, 4],
            jnp.sin(x[:, 5]) * x[:, 0], jnp.abs(x[:, 2])]
    return x, jnp.stack(cols[:k], axis=1), x[:m]


# -- parity: one block-CG vs k independent solves ----------------------------


@pytest.mark.parametrize("name", BACKENDS)
@pytest.mark.parametrize("kind", ALL_FAMILIES)
@pytest.mark.parametrize("k", [1, 3, 8])
def test_multi_rhs_matches_column_loop(name, kind, k):
    """The panel solve shares the preconditioner and the K_nM streaming, but
    every column's solution must match its own single-RHS fit (the PR 3
    column loop) to CG/fp32 tolerance."""
    kern = make_kernel(kind, sigma=1.7, kappa_sq=10.0)
    x, y, z = _problem(k=k)
    multi = falkon_fit(kern, x, y, z, 1e-3, iters=10, backend=name)
    assert multi.alpha.shape == (z.shape[0], k)
    pred = multi.predict(x)
    assert pred.shape == (x.shape[0], k)
    for j in range(k):
        col = falkon_fit(kern, x, y[:, j], z, 1e-3, iters=10, backend=name)
        ref = col.predict(x)
        rel = float(jnp.linalg.norm(pred[:, j] - ref)
                    / jnp.maximum(jnp.linalg.norm(ref), 1e-30))
        assert rel < 1e-3, (kind, name, j, rel)


def test_multi_rhs_host_path_matches_fused():
    """fused=False drives the same panel CG from the host loop."""
    kern = make_kernel("gaussian", sigma=1.5)
    x, y, z = _problem(k=3)
    fused = falkon_fit(kern, x, y, z, 1e-3, iters=20, backend="jnp")
    host = falkon_fit(kern, x, y, z, 1e-3, iters=20, backend="jnp", fused=False)
    rel = float(jnp.linalg.norm(fused.predict(x) - host.predict(x))
                / jnp.linalg.norm(host.predict(x)))
    assert rel < 1e-3


def test_multi_output_callback_rejected():
    kern = make_kernel("gaussian", sigma=1.5)
    x, y, z = _problem(k=2)
    with pytest.raises(ValueError, match="single-output"):
        falkon_fit(kern, x, y, z, 1e-3, callback=lambda i, m: None)


# -- the k-bucketed fused-fit cache ------------------------------------------


def test_fused_cache_k_bucket_zero_retrace():
    """k is padded to a pow2 column bucket: every RHS count in a bucket
    shares one executable (m=44 / iters=13 are unique to this test so other
    files' fits cannot mask the traces)."""
    kern = make_kernel("gaussian", sigma=1.5)
    x, y8, z = _problem(m=44, k=8)
    t0 = falkon_mod._FUSED_FIT_TRACES
    falkon_fit(kern, x, y8[:, :3], z, 1e-3, iters=13, backend="jnp")
    assert falkon_mod._FUSED_FIT_TRACES == t0 + 1  # k=3 compiled bucket kb=4
    falkon_fit(kern, x, y8[:, :4], z, 1e-3, iters=13, backend="jnp")
    assert falkon_mod._FUSED_FIT_TRACES == t0 + 1  # k=4: same bucket, no trace
    falkon_fit(kern, x, y8[:, :5], z, 1e-3, iters=13, backend="jnp")
    assert falkon_mod._FUSED_FIT_TRACES == t0 + 2  # k=5 -> bucket kb=8
    falkon_fit(kern, x, y8, z, 1e-3, iters=13, backend="jnp")
    assert falkon_mod._FUSED_FIT_TRACES == t0 + 2  # k=8 rides the kb=8 bucket
    falkon_fit(kern, x, y8[:, 0], z, 1e-3, iters=13, backend="jnp")
    assert falkon_mod._FUSED_FIT_TRACES == t0 + 3  # single-output: kb=1


def test_k_bucket_padding_columns_are_inert():
    """A k=3 fit runs in the kb=4 bucket with a zero fourth column; its
    presence must not perturb the real columns (vs a k=4 fit whose fourth
    column IS explicitly zero)."""
    kern = make_kernel("gaussian", sigma=1.5)
    x, y, z = _problem(k=3)
    a = falkon_fit(kern, x, y, z, 1e-3, iters=15, backend="jnp")
    b = falkon_fit(kern, x, jnp.pad(y, ((0, 0), (0, 1))), z, 1e-3, iters=15,
                   backend="jnp")
    np.testing.assert_array_equal(a.alpha, b.alpha[:, :3])
    np.testing.assert_array_equal(b.alpha[:, 3], jnp.zeros(z.shape[0]))


# -- the mask-panel seam: per-column row exclusion ---------------------------


def _mask_panel(n, k, seed=5):
    """A (n, k) 0/1 panel with ~25% of rows excluded per column (and one
    all-ones column so the unmasked fast path is exercised in-panel)."""
    key = jax.random.PRNGKey(seed)
    panel = (jax.random.uniform(key, (n, k)) > 0.25).astype(jnp.float32)
    return panel.at[:, 0].set(1.0) if k > 1 else panel


@pytest.mark.parametrize("name", MASK_BACKENDS)
@pytest.mark.parametrize("kind", MASK_FAMILIES)
@pytest.mark.parametrize("k", [1, 3, 8])
def test_masked_quadratic_backend_parity(name, kind, k):
    """Masked K_nM^T diag(m_j) K_nM v_j must agree across every backend
    (including the out-of-core stream) with the jnp reference at the
    documented 1e-4 scale-relative cross-backend parity."""
    kern = make_kernel(kind, sigma=1.7, kappa_sq=10.0)
    x, _, z = _problem(k=k)
    v = jax.random.normal(jax.random.PRNGKey(9), (z.shape[0], k))
    v = v[:, 0] if k == 1 else v
    mask = _mask_panel(x.shape[0], k)
    mask = mask[:, 0] if k == 1 else mask
    be = resolve_backend(name)
    ref = resolve_backend("jnp").knm_quadratic(kern, x, z, mask=mask)(v)
    got = be.knm_quadratic(kern, x, z, mask=mask)(v)
    assert got.shape == ref.shape
    scale = float(jnp.max(jnp.abs(ref)))
    err = float(jnp.max(jnp.abs(got - ref))) / scale
    # the mask multiply must add no error beyond the backend's own unmasked
    # cross-backend noise (laplacian-on-sharded already sits at ~2e-4 from
    # the shard_map |x-z| reduction — pre-existing, not a mask artifact)
    base_ref = resolve_backend("jnp").knm_quadratic(kern, x, z)(v)
    base_got = be.knm_quadratic(kern, x, z)(v)
    base = float(jnp.max(jnp.abs(base_got - base_ref))) / float(jnp.max(jnp.abs(base_ref)))
    assert err < max(1e-4, 2.0 * base), (name, kind, k, err, base)


@pytest.mark.parametrize("name", MASK_BACKENDS)
@pytest.mark.parametrize("k", [1, 3])
def test_masked_knm_t_backend_parity(name, k):
    """knm_t folds the mask into the targets: K_nM^T (mask * y) on every
    backend equals the jnp reference."""
    kern = make_kernel("gaussian", sigma=1.7)
    x, y, z = _problem(k=k)
    mask = _mask_panel(x.shape[0], k)
    mask = mask[:, 0] if k == 1 else mask
    ref = resolve_backend("jnp").knm_t(kern, x, z, y, mask=mask)
    got = resolve_backend(name).knm_t(kern, x, z, y, mask=mask)
    scale = float(jnp.max(jnp.abs(ref)))
    assert float(jnp.max(jnp.abs(got - ref))) / scale < 1e-4, (name, k)


@pytest.mark.parametrize("name", MASK_BACKENDS)
def test_all_ones_mask_is_bit_identical(name):
    """mask=ones must produce bit-for-bit the unmasked program's output on
    every backend — the masked path multiplies by 1.0 between the same two
    contractions, in the same order (mask=None additionally skips the
    multiply entirely; this pins that the mask insertion point is exact)."""
    kern = make_kernel("gaussian", sigma=1.7)
    x, y, z = _problem(k=3)
    v = jax.random.normal(jax.random.PRNGKey(9), (z.shape[0], 3))
    be = resolve_backend(name)
    ones = jnp.ones_like(y)
    np.testing.assert_array_equal(
        np.asarray(be.knm_quadratic(kern, x, z, mask=ones)(v)),
        np.asarray(be.knm_quadratic(kern, x, z)(v)))
    np.testing.assert_array_equal(
        np.asarray(be.knm_t(kern, x, z, y, mask=ones)),
        np.asarray(be.knm_t(kern, x, z, y)))


def test_masked_quadratic_equals_dense_reference():
    """Column j of the masked op is literally K_nM^T diag(m_j) K_nM v_j —
    checked against the dense einsum on small shapes."""
    kern = make_kernel("gaussian", sigma=1.7)
    x, _, z = _problem(n=150, m=24, k=3)
    v = jax.random.normal(jax.random.PRNGKey(9), (z.shape[0], 3))
    mask = _mask_panel(x.shape[0], 3)
    g = kern.cross(x, z)
    dense = jnp.einsum("nm,nk,nj,jk->mk", g, mask, g, v)
    got = resolve_backend("jnp").knm_quadratic(kern, x, z, mask=mask)(v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(dense),
                               rtol=1e-4, atol=1e-4)


def test_mask_none_stays_bit_identical_program():
    """mask=None takes the original (pre-PR 9) program path: repeated calls
    are bit-identical to each other, and falkon_fit without row_mask is
    unchanged by the seam extension."""
    kern = make_kernel("gaussian", sigma=1.5)
    x, y, z = _problem(k=3)
    a = falkon_fit(kern, x, y, z, 1e-3, iters=10, backend="jnp")
    b = falkon_fit(kern, x, y, z, 1e-3, iters=10, backend="jnp")
    np.testing.assert_array_equal(np.asarray(a.alpha), np.asarray(b.alpha))


def test_falkon_fit_row_mask_validation():
    kern = make_kernel("gaussian", sigma=1.5)
    x, y, z = _problem(k=3)
    with pytest.raises(ValueError, match="row_mask"):
        falkon_fit(kern, x, y, z, 1e-3, row_mask=jnp.ones((x.shape[0],)))


def test_falkon_fit_row_mask_equals_subset_fit():
    """A fused panel fit where column j masks out a row block must equal a
    from-scratch fit on the kept rows (fold-local n in the regularization
    — the exact-CV semantics at the falkon_fit level)."""
    kern = make_kernel("gaussian", sigma=1.5)
    x, y, z = _problem(k=2)
    n = x.shape[0]
    keep = jnp.arange(n) >= 60
    mask = jnp.stack([jnp.ones(n), keep.astype(jnp.float32)], axis=1)
    panel = falkon_fit(kern, x, y * mask, z, 1e-2, iters=25, backend="jnp",
                       row_mask=mask)
    sub = falkon_fit(kern, x[keep], y[keep, 1], z, 1e-2, iters=25,
                     backend="jnp")
    full = falkon_fit(kern, x, y[:, 0], z, 1e-2, iters=25, backend="jnp")
    for col, ref in ((1, sub), (0, full)):
        rel = float(jnp.linalg.norm(panel.alpha[:, col] - ref.alpha)
                    / jnp.linalg.norm(ref.alpha))
        assert rel < 1e-4, (col, rel)


# -- per-column convergence masking ------------------------------------------


def test_cg_freezes_converged_columns():
    """A zero RHS column (rs0 = 0) must stay exactly zero while the live
    columns converge; an easy column frozen early must not drift."""
    key = jax.random.PRNGKey(0)
    a = jax.random.normal(key, (40, 40))
    a = a @ a.T / 40.0 + jnp.eye(40)
    b_live = jax.random.normal(jax.random.PRNGKey(1), (40,))
    b = jnp.stack([b_live, jnp.zeros(40)], axis=1)
    sol = cg(lambda v: a @ v, b, 60)
    np.testing.assert_array_equal(sol[:, 1], jnp.zeros(40))
    np.testing.assert_allclose(a @ sol[:, 0], b_live, rtol=1e-4, atol=1e-4)
    # panel solve of the live column agrees with the single-RHS path
    single = cg(lambda v: a @ v, b_live, 60)
    np.testing.assert_allclose(sol[:, 0], single, rtol=1e-4, atol=1e-5)


# -- KFoldSweep: model selection as one multi-RHS solve per lambda -----------


LAMS = (1e-2, 1e-4, 1e-6)


def _sweep_problem(n=400, d=6, seed=0):
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (n, d))
    y = (jnp.sin(2 * x[:, 0]) + 0.3 * x[:, 1] ** 2
         + 0.05 * jax.random.normal(jax.random.PRNGKey(seed + 1), (n,)))
    return x, y


def test_kfold_sweep_matches_naive_per_fold_refits():
    """Every (lam, fold) score must equal the naive loop: a full single-RHS
    refit on the fold's TRAINING ROWS ONLY (exact row-exclusion — held-out
    rows contribute nothing to the operator, fold-local n in the
    regularization), scored on the held-out rows. tests/test_scenarios.py
    pins the well-conditioned end of this parity at 1e-6."""
    from repro.api.sweep import fold_ids

    x, y = _sweep_problem()
    folds = 4
    sweep = KFoldSweep(kernel="gaussian", sigma=1.5, sampler=UniformSampler(m=64),
                       lams=LAMS, folds=folds, iters=15, backend="jnp", seed=0)
    res = sweep.run(x, y)
    assert res.scores.shape == (len(LAMS), folds)

    kern = make_kernel("gaussian", sigma=1.5)
    k_sample, k_fold = jax.random.split(jax.random.PRNGKey(0))
    fid = fold_ids(k_fold, x.shape[0], folds)
    np.testing.assert_array_equal(res.fold_id, fid)
    cs = UniformSampler(m=64).sample(k_sample, x, kern, backend="jnp")
    m = int(cs.count)
    centers, a_diag = x[cs.idx[:m]], cs.weight[:m]
    for li, lam in enumerate(LAMS):
        for f in range(folds):
            train = np.asarray(fid != f)
            model = falkon_fit(kern, x[train], y[train], centers, lam,
                               a_diag=a_diag, iters=15, backend="jnp")
            held = np.asarray(fid == f)
            mse = float(jnp.mean((model.predict(x[held]) - y[held]) ** 2))
            got = float(res.scores[li, f])
            assert abs(mse - got) < 1e-3 * max(1.0, abs(mse)), (li, f, mse, got)
    assert res.best_lam == LAMS[res.best_index]
    assert float(res.mean_scores[res.best_index]) == float(jnp.min(res.mean_scores))


def test_kfold_sweep_rides_fused_cache():
    """The whole lambda grid after the first fit is cache hits: fold count
    fixes the k bucket, lam is traced, centers are warm-started."""
    x, y = _sweep_problem(seed=7)
    sweep = KFoldSweep(kernel="gaussian", sigma=1.5, sampler=UniformSampler(m=52),
                       lams=LAMS, folds=4, iters=12, backend="jnp", seed=3)
    res1 = sweep.run(x, y)
    t0 = falkon_mod._FUSED_FIT_TRACES
    res2 = sweep.run(x, y)  # same shapes end to end -> zero retraces
    assert falkon_mod._FUSED_FIT_TRACES == t0
    np.testing.assert_allclose(res1.scores, res2.scores, rtol=1e-6, atol=1e-7)


def test_kfold_sweep_validates_inputs():
    x, y = _sweep_problem(n=40)
    with pytest.raises(ValueError, match="single-output"):
        KFoldSweep(lams=(1e-3,)).run(x, jnp.stack([y, y], axis=1))
    with pytest.raises(ValueError, match="folds"):
        KFoldSweep(lams=(1e-3,), folds=1).run(x, y)


def test_fold_ids_are_balanced():
    from repro.api.sweep import fold_ids

    fid = fold_ids(jax.random.PRNGKey(0), 103, 5)
    sizes = [int(jnp.sum(fid == f)) for f in range(5)]
    assert min(sizes) >= max(sizes) - 1 and sum(sizes) == 103


def test_kfold_sweep_center_set_bypass():
    """center_set= skips the sampler (e.g. one BLESS ladder shared across
    sweeps) and is reused for every lambda."""
    x, y = _sweep_problem(n=300)
    kern = make_kernel("gaussian", sigma=1.5)
    cs = UniformSampler(m=48).sample(jax.random.PRNGKey(5), x, kern, backend="jnp")
    sweep = KFoldSweep(kernel=kern, lams=(1e-3, 1e-5), folds=3, iters=10,
                       backend="jnp")
    res = sweep.run(x, y, center_set=cs)
    assert res.center_set is cs
    assert res.scores.shape == (2, 3)
