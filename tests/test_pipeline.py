"""GPipe pipeline parallelism: pipelined == sequential, fwd and grad
(subprocess with 4 forced host devices)."""
import os
import subprocess
import sys
import textwrap

import pytest

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp
    from repro.training.pipeline import pipeline_apply, stack_stages

    n_stages, n_mb, mb, d = 4, 8, 2, 16
    n_layers = 8
    mesh = jax.make_mesh((4,), ("pipe",))
    key = jax.random.PRNGKey(0)
    w = jax.random.normal(key, (n_layers, d, d)) * (0.5 / d**0.5)
    x = jax.random.normal(jax.random.PRNGKey(1), (n_mb, mb, d))

    def stage_fn(wstage, xm):
        # wstage: (layers_per_stage, d, d)
        def body(x, wl):
            return jnp.tanh(x @ wl), None
        out, _ = jax.lax.scan(body, xm, wstage[0] if wstage.ndim == 4 else wstage)
        return out

    # sequential reference
    def seq(w, x):
        def body(xc, wl):
            return jnp.tanh(xc @ wl), None
        out, _ = jax.lax.scan(body, x.reshape(-1, d), w)
        return out.reshape(x.shape)

    wst = stack_stages(w, n_stages)  # (4, 2, d, d)
    run = pipeline_apply(stage_fn, n_stages, n_mb, mesh)
    got = jax.jit(run)(wst, x)
    want = seq(w, x)
    err = float(jnp.max(jnp.abs(got - want)))
    assert err < 1e-5, err

    # gradients flow through the schedule (GPipe backward)
    def loss_p(wst, x):
        return jnp.sum(run(wst, x) ** 2)
    def loss_s(w, x):
        return jnp.sum(seq(w, x) ** 2)
    gp = jax.grad(loss_p)(wst, x).reshape(w.shape)
    gs = jax.grad(loss_s)(w, x)
    gerr = float(jnp.max(jnp.abs(gp - gs)))
    assert gerr < 1e-4, gerr
    print("PIPELINE_OK", err, gerr)
""")


@pytest.mark.slow
def test_gpipe_matches_sequential():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))
    out = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600)
    assert "PIPELINE_OK" in out.stdout, out.stdout + out.stderr
